"""Federated LLM scenario: the model zoo wired into the federation core.

Covers the leaf-family subset machinery end to end — `LeafSpec.family_view`,
the `family(...)` transport stage, `PartialFedAvg(families=...)` — plus the
tier-1 headline: ≥2 async nodes training a smoke transformer (with LoRA
adapters) through a real delta-chain ``WeightStore``, and adapter-only
federation leaving every non-federated leaf bit-exact.

The property oracle is ``strategies_ref.PartialFedAvgRef`` driven by
``FamilyView.pattern`` — the single regex equivalent of the family selector,
so flat-masked family aggregation is checked against the frozen per-leaf
reference.
"""
import threading
import time

import jax
import numpy as np
import pytest

from _hyp import given, settings, strategies

from repro.core import (
    AsyncFederatedNode,
    FederatedCallback,
    InMemoryFolder,
    NodeUpdate,
    WeightStore,
    family_transport_spec,
    normalize_transport,
    run_threaded,
)
from repro.core.partition import partition_sequence_dataset
from repro.core.strategies import FedAvg, PartialFedAvg
from repro.core.strategies_ref import PartialFedAvgRef
from repro.core.tree import LeafSpec, tree_to_numpy
from repro.data import lm_batch_iterator, make_synthetic_wikitext
from repro.models import ModelConfig, build_model
from repro.optim import adamw, chain_clip
from repro.training import Trainer

TINY = ModelConfig(
    name="tiny-lm", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
    vocab_size=512, activation="gelu", dtype="float32", lora_rank=4,
)
SEQ, BATCH = 16, 2


def _tiny_params(seed=0):
    model = build_model(TINY)
    return model, tree_to_numpy(model.init(jax.random.PRNGKey(seed)))


# --- the headline tier-1 scenario -------------------------------------------


def test_async_nodes_train_llm_through_delta_chain_store():
    """≥2 async nodes train the smoke transformer on non-IID shards through a
    real WeightStore with a delta-chain pipeline spec."""
    model, init = _tiny_params()
    data = make_synthetic_wikitext(vocab_size=TINY.vocab_size, train_tokens=4_000, seed=0)
    shards = partition_sequence_dataset(data.train_tokens, 2)
    folder = InMemoryFolder()

    def client(i):
        trainer = Trainer(
            loss_fn=lambda p, b, r: model.loss(p, b),
            optimizer=chain_clip(adamw(1e-3), 1.0),
            init_params=init, seed=i, name=f"node{i}",
        )
        node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                                  node_id=f"node{i}", transport="delta(chain=4)")
        cb = FederatedCallback(node, num_examples_per_epoch=2 * BATCH)
        trainer.fit(lambda e: lm_batch_iterator(shards[i], batch_size=BATCH,
                                                seq_len=SEQ, seed=i, epoch=e),
                    epochs=3, steps_per_epoch=2, callbacks=[cb])
        # async nodes never wait for each other, so a fast node may finish its
        # epochs before its peer deposits anything; keep federating until this
        # node has aggregated at least once (deterministic, not timing luck)
        deadline = time.monotonic() + 60.0
        while node.num_aggregations == 0 and time.monotonic() < deadline:
            node.update_parameters(trainer.host_params(), num_examples=BATCH)
            time.sleep(0.02)
        return {"loss": trainer.log[-1]["loss"], "aggs": node.num_aggregations,
                "stats": node.transport_stats()}

    results = run_threaded([lambda i=i: client(i) for i in range(2)])
    assert all(r.error is None for r in results), [r.traceback for r in results]
    # federation actually happened through the store, in both directions
    assert all(r.result["aggs"] >= 1 for r in results)
    for r in results:
        assert np.isfinite(r.result["loss"])
        assert r.result["stats"]["bytes_written"] > 0
        assert r.result["stats"]["bytes_read"] > 0


def test_adapter_only_federation_semantics():
    """families=('adapters',) on the node federates exactly the LoRA leaves:
    adapter leaves average across nodes, every other leaf stays the node's
    own, bit-exact."""
    model, p_a = _tiny_params(seed=0)
    p_b = jax.tree.map(lambda x: x + np.float32(0.01), p_a)
    p_b = tree_to_numpy(p_b)
    folder = InMemoryFolder()
    node_a = AsyncFederatedNode(shared_folder=folder, node_id="a",
                                families=("adapters",))
    node_b = AsyncFederatedNode(shared_folder=folder, node_id="b",
                                families=("adapters",))
    assert isinstance(node_a.strategy, PartialFedAvg)
    assert folder is node_a.store.folder

    assert node_a.update_parameters(p_a, num_examples=1) is None  # no peers yet
    agg = node_b.update_parameters(p_b, num_examples=1)
    assert agg is not None

    spec = LeafSpec.of(p_b)
    view = spec.family_view(("adapters",))
    assert view.num_params > 0
    agg_leaves = jax.tree.leaves(tree_to_numpy(agg))
    a_leaves, b_leaves = jax.tree.leaves(p_a), jax.tree.leaves(p_b)
    for fam, out, la, lb, path in zip(view.leaf_names, agg_leaves, a_leaves,
                                      b_leaves, spec.paths):
        if fam == "adapters":
            np.testing.assert_allclose(out, (la + lb) / 2, rtol=1e-5, atol=1e-6,
                                       err_msg=path)
        else:
            assert np.array_equal(out, lb), f"non-federated leaf drifted: {path}"


def test_adapter_only_wire_is_smaller_than_full():
    """After the anchor round, family(adapters=full) pushes ship a small
    fraction of the full-model bytes."""
    model, p = _tiny_params()
    folder = InMemoryFolder()
    store = WeightStore(folder, families=("adapters",))
    store.push(NodeUpdate(p, num_examples=1, node_id="n", counter=0))
    anchor_bytes = store.transport_stats()["bytes_written"]
    p2 = jax.tree.map(lambda x: x + np.float32(1e-3), p)
    store.push(NodeUpdate(tree_to_numpy(p2), num_examples=1, node_id="n", counter=1))
    family_bytes = store.transport_stats()["bytes_written"] - anchor_bytes
    spec = LeafSpec.of(p)
    frac = spec.family_view(("adapters",)).num_params / spec.num_params
    assert family_bytes < anchor_bytes * max(0.2, 4 * frac)
    # and a vanilla reader decodes the family blob with zero configuration
    update = WeightStore(folder).pull_node("n")
    view = spec.family_view(("adapters",))
    got = spec.flatten(update.params)
    np.testing.assert_allclose(got[view.indices],
                               spec.flatten(p2)[view.indices], rtol=1e-6)


# --- FamilyView on the real model -------------------------------------------


def test_family_view_selects_lora_leaves():
    model, p = _tiny_params()
    spec = LeafSpec.of(p)
    view = spec.family_view(("adapters",))
    assert view.paths and all("lora_" in path for path in view.paths)
    # both A and B matrices (layers are scan-stacked: one leaf, leading dim L)
    assert sum("lora_a" in path for path in view.paths) == 1
    assert sum("lora_b" in path for path in view.paths) == 1
    assert view.num_params == TINY.n_layers * (
        TINY.d_model * TINY.lora_rank + TINY.lora_rank * TINY.d_model)
    # extract/scatter are a gather/scatter-back pair
    flat = spec.flatten(p)
    sub = view.extract(flat)
    out = np.zeros_like(flat)
    view.scatter(sub, out)
    assert np.array_equal(out[view.indices], flat[view.indices])
    assert not out[~view.mask].any()
    # per-family indices partition the view
    np.testing.assert_array_equal(view.indices_of("adapters"), view.indices)


def test_family_view_errors():
    model, p = _tiny_params()
    spec = LeafSpec.of(p)
    with pytest.raises(KeyError, match="unknown leaf family"):
        spec.family_view(("no_such_family",))
    no_lora = build_model(TINY.replace(lora_rank=0))
    spec2 = LeafSpec.of(tree_to_numpy(no_lora.init(jax.random.PRNGKey(0))))
    with pytest.raises(ValueError, match="match no leaf"):
        spec2.family_view(("adapters",))


def test_lora_changes_forward_pass():
    """The adapters the federation ships are live weights, not dead params:
    perturbing lora_b changes the model's loss."""
    model, p = _tiny_params()
    # varying tokens: with a constant sequence every value vector is equal and
    # the attention output is q-independent, hiding the adapters entirely
    batch = {"tokens": np.arange(8, dtype=np.int32)[None, :],
             "labels": np.arange(1, 9, dtype=np.int32)[None, :]}
    loss0, _ = model.loss(p, batch)
    spec = LeafSpec.of(p)
    flat = spec.flatten(p)
    view = spec.family_view(("adapters",))
    flat[view.indices] += 0.5  # lora_b leaves zero-init → this activates them
    loss1, _ = model.loss(spec.unflatten(flat), batch)
    assert not np.allclose(float(loss0), float(loss1))


# --- family transport grammar ------------------------------------------------


def test_family_spec_grammar_canonicalization():
    assert normalize_transport("family(adapters)") == "family(adapters=full)"
    assert (normalize_transport("family(embeddings=quantized|zstd, adapters=full)")
            == "family(adapters=full,embeddings=quantized)|zstd")
    assert (normalize_transport("family(adapters=delta)|npz")
            == "family(adapters=delta)|npz")
    assert family_transport_spec("adapters") == "family(adapters=full)"
    assert (family_transport_spec(("norms", "adapters"))
            == "family(adapters=full,norms=full)")
    assert (family_transport_spec({"embeddings": "quantized", "adapters": "full"})
            == "family(adapters=full,embeddings=quantized)")


def test_family_spec_grammar_rejects_bad_specs():
    with pytest.raises(ValueError):
        normalize_transport("family()")
    with pytest.raises(ValueError, match="sub-policy"):
        normalize_transport("family(adapters=topk)")
    with pytest.raises(ValueError, match="whole-pipeline"):
        normalize_transport("family(adapters=delta(chain=2))")
    with pytest.raises(ValueError, match="envelope"):
        normalize_transport("family(adapters=full|zstd,norms=full|npz)")
    with pytest.raises(ValueError):
        family_transport_spec(())
    with pytest.raises(ValueError, match="not both"):
        WeightStore(InMemoryFolder(), transport="delta", families=("adapters",))


# --- family-subset ≡ masked PartialFedAvg (frozen per-leaf oracle) -----------


_FAMILY_CHOICES = [("adapters",), ("norms",), ("embeddings",),
                   ("adapters", "norms"), ("adapters", "embeddings", "norms")]


def _property_tree(rng):
    """A small LM-shaped tree exercising every family plus unmatched leaves
    (including a non-f32 leaf no family touches)."""
    f = lambda *s: rng.normal(size=s).astype(np.float32)
    return {
        "embed": {"w": f(12, 4)},
        "blocks": {
            "u0": {"attn": {"wq": {"w": f(4, 4)}, "lora_a": {"w": f(4, 2)},
                            "lora_b": {"w": f(2, 4)}},
                   "norm1": {"scale": f(4)}},
            "u1": {"mlp": {"w": f(4, 8)}, "norm2": {"scale": f(4)}},
        },
        "unembed": {"w": f(4, 12)},
        "step": np.int64(rng.integers(0, 100)),
    }


@settings(max_examples=15, deadline=None)
@given(strategies.integers(0, 2**31 - 1),
       strategies.sampled_from(_FAMILY_CHOICES),
       strategies.integers(1, 4))
def test_family_subset_matches_masked_oracle(seed, families, n_peers):
    rng = np.random.default_rng(seed)
    own = NodeUpdate(_property_tree(rng), num_examples=int(rng.integers(1, 9)),
                     node_id="own", counter=0)
    peers = [NodeUpdate(_property_tree(rng), num_examples=int(rng.integers(1, 9)),
                        node_id=f"p{i}", counter=0) for i in range(n_peers)]
    view = LeafSpec.of(own.params).family_view(families)
    ours = PartialFedAvg(families=families).aggregate(own, peers)
    oracle = PartialFedAvgRef(shared_pattern=view.pattern).aggregate(own, peers)
    ours_l, oracle_l = jax.tree.leaves(ours), jax.tree.leaves(oracle)
    own_l = jax.tree.leaves(own.params)
    for fam, a, b, o in zip(view.leaf_names, ours_l, oracle_l, own_l):
        if fam is None:
            # personal leaves are identical to own in BOTH paths, bit-exact —
            # including the int64 'step' leaf that makes the tree non-f32_exact
            assert np.array_equal(np.asarray(a), np.asarray(o))
            assert np.asarray(a).dtype == np.asarray(o).dtype
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), rtol=1e-5, atol=1e-5)


# --- non-federated leaves: bit-exact through the whole loop ------------------


def test_nonfederated_leaves_bitexact_through_push_pull_set_params():
    """int / f64 leaves outside the family survive push → pull →
    PartialFedAvg → Trainer.set_params without any value or dtype drift."""
    rng = np.random.default_rng(0)
    tree = {
        "attn": {"lora_a": {"w": rng.normal(size=(8, 2)).astype(np.float32)}},
        "head": {"w": rng.normal(size=(16,)).astype(np.float32)},
        "vocab_freq": (rng.integers(0, 1 << 40, size=(6,))).astype(np.int64),
        "threshold": np.float64(0.1234567890123456789),  # not f32-representable
    }
    folder = InMemoryFolder()
    WeightStore(folder, families=("adapters",)).push(
        NodeUpdate(tree, num_examples=1, node_id="n", counter=0))
    pulled = WeightStore(folder).pull_node("n")
    # mixed-dtype trees are not f32-embeddable → the codec ships exact blobs
    assert np.array_equal(pulled.params["vocab_freq"], tree["vocab_freq"])
    assert pulled.params["vocab_freq"].dtype == np.int64
    assert float(pulled.params["threshold"]) == float(tree["threshold"])

    peer = NodeUpdate(jax.tree.map(np.copy, tree), num_examples=1,
                      node_id="peer", counter=0)
    agg = PartialFedAvg(families=("adapters",)).aggregate(pulled, [peer])
    assert np.array_equal(agg["vocab_freq"], tree["vocab_freq"])
    assert agg["vocab_freq"].dtype == np.int64

    trainer = Trainer(loss_fn=lambda p, b, r: (p["head"]["w"].sum(), {}),
                      optimizer=adamw(1e-3), init_params=tree, jit=False)
    trainer.set_params(agg)
    got = jax.tree.leaves(trainer.params)
    for want, have in zip(jax.tree.leaves(tree), got):
        assert np.asarray(want).dtype == np.asarray(have).dtype
    assert np.array_equal(np.asarray(trainer.params["vocab_freq"]),
                          tree["vocab_freq"])


# --- satellite regressions ----------------------------------------------------


def test_lm_batch_iterator_reaches_last_window():
    """Regression: the start-index bound excluded the final window (the only
    one whose labels reach the stream's last token)."""
    tokens = np.arange(20, dtype=np.int32)  # seq_len 16 → starts 0..3 valid
    starts_seen = set()
    for seed in range(40):
        batch = next(iter(lm_batch_iterator(tokens, batch_size=8, seq_len=16,
                                            seed=seed)))
        starts_seen.update(int(row[0]) for row in batch["tokens"])
        assert all(row[-1] == row[0] + 15 for row in batch["tokens"])
    assert 3 in starts_seen  # rng.integers(0, n) could never draw start n=3
    # exact-minimum stream: exactly one valid window, labels end on last token
    tokens = np.arange(17, dtype=np.int32)
    batch = next(iter(lm_batch_iterator(tokens, batch_size=4, seq_len=16, seed=0)))
    assert np.array_equal(batch["tokens"][0], np.arange(16))
    assert batch["labels"][0][-1] == 16
    with pytest.raises(ValueError, match="too short"):
        next(iter(lm_batch_iterator(np.arange(16, dtype=np.int32),
                                    batch_size=1, seq_len=16)))


def test_run_epoch_defers_metric_host_sync_to_epoch_end():
    """Regression: per-step float(v) blocked on every step's result. Metric
    leaves must be materialized only after the last step has been issued."""
    issued = {"n": 0}
    conversions = []

    class Probe:
        def __array__(self, dtype=None, copy=None):
            conversions.append(issued["n"])
            return np.float32(1.0)

        def __float__(self):
            conversions.append(issued["n"])
            return 1.0

    trainer = Trainer(loss_fn=lambda p, b, r: (p["w"].sum(), {}),
                      optimizer=adamw(1e-3),
                      init_params={"w": np.zeros((2,), np.float32)}, jit=False)

    def fake_step(params, opt_state, batch, rng):
        issued["n"] += 1
        return params, opt_state, {"loss": Probe()}

    trainer._train_step = fake_step
    logs = trainer.run_epoch([None] * 5)
    assert issued["n"] == 5
    assert logs["loss"] == pytest.approx(1.0)
    assert conversions and all(c == 5 for c in conversions), (
        f"metric materialized mid-epoch at steps {sorted(set(conversions))}")


def test_crashed_fit_still_runs_teardown():
    """fit(crash_at_epoch=...) raises but on_train_end still fires (the
    prefetcher-leak guard lives on that hook)."""
    calls = []

    class Cb:
        def on_train_begin(self, t): calls.append("begin")
        def on_epoch_begin(self, t, e): pass
        def on_epoch_end(self, t, e, logs): calls.append(f"epoch{e}")
        def on_train_end(self, t): calls.append("end")

    trainer = Trainer(loss_fn=lambda p, b, r: (p["w"].sum(), {}),
                      optimizer=adamw(1e-3),
                      init_params={"w": np.zeros((2,), np.float32)}, jit=False)
    with pytest.raises(RuntimeError, match="injected crash"):
        trainer.fit(lambda e: [None], epochs=4, callbacks=[Cb()], crash_at_epoch=1)
    assert calls == ["begin", "epoch0", "end"]


def test_crashed_fit_does_not_leak_prefetcher_thread():
    """The FederatedCallback + try/finally pair: an injected crash must stop
    the store's background prefetcher."""
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=InMemoryFolder(),
                              node_id="leaky", prefetch_interval=0.01)
    cb = FederatedCallback(node, num_examples_per_epoch=1)
    trainer = Trainer(loss_fn=lambda p, b, r: (p["w"].sum(), {}),
                      optimizer=adamw(1e-3),
                      init_params={"w": np.zeros((2,), np.float32)}, jit=False)
    assert any(t.name == "weightstore-prefetch" and t.is_alive()
               for t in threading.enumerate())
    with pytest.raises(RuntimeError, match="injected crash"):
        trainer.fit(lambda e: [None], epochs=5, callbacks=[cb], crash_at_epoch=1)
    assert not any(t.name == "weightstore-prefetch" and t.is_alive()
                   for t in threading.enumerate())
