"""Cross-process federation: the serverless claim with real OS processes.

Every client here is a separate interpreter (spawn start method) sharing
nothing but a DiskFolder directory — the honest version of the paper's "any
remote folder suffices" claim. Child targets must be module-level functions
(spawn pickles them by qualified name).
"""
import os
import signal
import time

import numpy as np
import pytest

from repro.core import (
    AsyncFederatedNode,
    DiskFolder,
    NodeUpdate,
    ProcessCrashed,
    ShardedWeightStore,
    WeightStore,
    run_multiprocess,
)
from repro.core.gossip import GROUP_PEER_PREFIX
from repro.core.strategies import FedAvg

pytestmark = pytest.mark.multiprocess


# --- child targets (module-level: picklable under spawn) --------------------


def _returns_value(x):
    return x * 2


def _raises():
    raise ValueError("injected failure")


def _hangs_forever():
    while True:
        time.sleep(0.1)


def _atomic_writer(directory, blob_a, blob_b, iterations):
    folder = DiskFolder(directory)
    for i in range(iterations):
        folder.put("latest/w", blob_a if i % 2 == 0 else blob_b)
    folder.put("done", b"x")


def _push_update(directory, node_id, value, counter):
    store = WeightStore(DiskFolder(directory))
    store.push(NodeUpdate({"w": np.full((8,), float(value), np.float32)},
                          num_examples=3, node_id=node_id, counter=counter))
    return store.state_hash()


def _pull_update(directory, node_id):
    update = WeightStore(DiskFolder(directory)).pull_node(node_id)
    assert update is not None
    return {"value": float(update.params["w"][0]), "counter": update.counter,
            "num_examples": update.num_examples}


def _fed_client(directory, node_id, target, *, epochs, peers_required,
                die_after_pushes=None, max_wait=60.0):
    """Quadratic consensus client: local step pulls toward own target, the
    async federation step mixes in whatever peers have deposited.

    ``die_after_pushes`` turns the client into a crash victim: after that many
    federation pushes it hangs so the harness's SIGKILL lands mid-training.
    Survivors keep looping (past their nominal epoch count if necessary) until
    they have aggregated ``peers_required`` distinct peers, so the "survivors
    saw the dead node's deposit" assertion is deterministic, not timing luck.
    """
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=DiskFolder(directory),
                              node_id=node_id)
    w = np.zeros((4,), np.float32)
    seen_peers: set = set()
    deadline = time.monotonic() + max_wait
    epoch = 0
    while epoch < epochs or (len(seen_peers) < peers_required and time.monotonic() < deadline):
        w = w + 0.3 * (np.float32(target) - w)  # local "training"
        aggregated = node.update_parameters({"w": w}, num_examples=5)
        seen_peers.update(u.node_id for u in node.store.pull(exclude=node_id))
        if aggregated is not None:
            w = aggregated["w"]
        if die_after_pushes is not None and node.num_pushes >= die_after_pushes:
            while True:  # "mid-training": park here until SIGKILL arrives
                time.sleep(0.05)
        time.sleep(0.05)
        epoch += 1
    return {
        "final": w.tolist(),
        "epochs": epoch,
        "pushes": node.num_pushes,
        "aggregations": node.num_aggregations,
        "seen_peers": sorted(seen_peers),
    }


def _resumable_client(directory, node_id, epochs, die_after_pushes=None):
    """Crash-and-restart client: reports whether it bootstrapped from its own
    latest/ blob and where its counter started. ``die_after_pushes`` parks the
    client mid-training so the harness SIGKILL lands deterministically."""
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=DiskFolder(directory),
                              node_id=node_id)
    start_counter = node.counter
    resumed_from = None if node.resumed is None else float(node.resumed.params["w"][0])
    w = (np.asarray(node.resumed.params["w"]) if node.resumed is not None
         else np.zeros((4,), np.float32))
    for _ in range(epochs):
        w = w + np.float32(1.0)  # local "training": counts total progress
        aggregated = node.update_parameters({"w": w}, num_examples=5)
        if aggregated is not None:
            w = aggregated["w"]
        if die_after_pushes is not None and node.num_pushes >= die_after_pushes:
            while True:  # park mid-training until the SIGKILL arrives
                time.sleep(0.05)
    return {"start_counter": start_counter, "resumed_from": resumed_from,
            "final_counter": node.counter, "w0": float(w[0])}


def _sharded_fed_client(directory, node_id, group_map, num_groups, target, *,
                        epochs, max_wait=60.0):
    """Quadratic consensus client over a sharded gossip store: same contract
    as ``_fed_client`` but each process scans only its home group's folder;
    cross-group information arrives as ``group:<g>`` pseudo-peers."""
    store = ShardedWeightStore(f"shard{num_groups}+{directory}", group_of=group_map)
    node = AsyncFederatedNode(strategy=FedAvg(), store=store, node_id=node_id)
    w = np.zeros((4,), np.float32)
    seen: set = set()
    deadline = time.monotonic() + max_wait
    epoch = 0
    while epoch < epochs or (
        not any(p.startswith(GROUP_PEER_PREFIX) for p in seen)
        and time.monotonic() < deadline
    ):
        w = w + 0.3 * (np.float32(target) - w)
        aggregated = node.update_parameters({"w": w}, num_examples=5)
        seen.update(u.node_id for u in store.pull(exclude=node_id))
        if aggregated is not None:
            w = aggregated["w"]
        time.sleep(0.05)
        epoch += 1
    return {"final": w.tolist(), "pushes": node.num_pushes,
            "aggregations": node.num_aggregations, "seen_peers": sorted(seen)}


# --- harness contract -------------------------------------------------------


def test_run_multiprocess_collects_results_and_errors():
    res = run_multiprocess([(_returns_value, (21,)), _raises], names=["ok", "bad"])
    assert res[0].error is None and res[0].result == 42 and res[0].exitcode == 0
    assert isinstance(res[1].error, ProcessCrashed)
    assert "injected failure" in res[1].traceback


def test_run_multiprocess_sigkill_injection():
    t0 = time.monotonic()
    res = run_multiprocess([_hangs_forever], kill_after={0: 0.5}, join_timeout=30.0)
    assert isinstance(res[0].error, ProcessCrashed)
    assert res[0].exitcode == -signal.SIGKILL
    assert time.monotonic() - t0 < 25.0  # did not wait out the join timeout


# --- DiskFolder cross-process semantics -------------------------------------


def test_diskfolder_atomic_put_under_concurrent_reader(tmp_path):
    """Readers racing a writer in another process never observe a torn blob."""
    blob_a, blob_b = b"A" * 4096, b"B" * 8192
    folder = DiskFolder(str(tmp_path))
    res_holder = {}

    def read_loop():
        torn = 0
        reads = 0
        reader = DiskFolder(str(tmp_path))
        while reader.get("done") is None:
            blob = reader.get("latest/w")
            if blob is not None:
                reads += 1
                if blob != blob_a and blob != blob_b:
                    torn += 1
        res_holder["torn"], res_holder["reads"] = torn, reads

    import threading

    reader_thread = threading.Thread(target=read_loop, daemon=True)
    reader_thread.start()
    res = run_multiprocess([(_atomic_writer, (str(tmp_path), blob_a, blob_b, 200))])
    assert res[0].error is None
    reader_thread.join(timeout=30)
    assert not reader_thread.is_alive()
    assert res_holder["torn"] == 0
    assert res_holder["reads"] > 0
    assert folder.get("latest/w") in (blob_a, blob_b)


def test_diskfolder_state_hash_detects_cross_process_writes(tmp_path):
    folder = DiskFolder(str(tmp_path))
    h0 = folder.state_hash()
    res = run_multiprocess([(_push_update, (str(tmp_path), "remote", 1.0, 0))])
    assert res[0].error is None
    h1 = folder.state_hash()
    assert h0 != h1
    # the child and the parent compute identical hashes over identical state
    assert res[0].result == WeightStore(folder).state_hash()


def test_two_process_push_pull_roundtrip(tmp_path):
    res = run_multiprocess([
        (_push_update, (str(tmp_path), "writer", 7.5, 3)),
        (_pull_update, (str(tmp_path), "writer")),
    ])
    # NB: the pull client polls nothing — it may race the writer, so order the
    # processes: run writer first, then reader, each in its own interpreter.
    if res[1].error is not None:  # reader beat the writer: rerun reader alone
        res[1] = run_multiprocess([(_pull_update, (str(tmp_path), "writer"))])[0]
    assert res[0].error is None and res[1].error is None
    assert res[1].result == {"value": 7.5, "counter": 3, "num_examples": 3}


# --- the paper's robustness claim, across real processes ---------------------


def test_three_process_federation_survives_sigkill(tmp_path):
    """≥3 OS processes federate over a DiskFolder; one is SIGKILLed
    mid-training; the survivors finish and converge (async mode)."""
    targets = {"n0": 0.0, "n1": 1.0, "n2": 2.0}
    clients = [
        (_fed_client, (str(tmp_path), "n0", targets["n0"]),
         dict(epochs=10, peers_required=2)),
        (_fed_client, (str(tmp_path), "n1", targets["n1"]),
         dict(epochs=10, peers_required=2)),
        (_fed_client, (str(tmp_path), "n2", targets["n2"]),
         dict(epochs=10, peers_required=1, die_after_pushes=2)),
    ]
    res = run_multiprocess(clients, names=["n0", "n1", "n2"],
                           kill_after={2: 10.0}, join_timeout=120.0)

    # the victim died by SIGKILL, not by exception
    assert isinstance(res[2].error, ProcessCrashed)
    assert res[2].exitcode == -signal.SIGKILL

    # the survivors finished all their epochs, unblocked
    for r in res[:2]:
        assert r.error is None, r.traceback
        assert r.exitcode == 0
        assert r.result["epochs"] >= 10
        assert r.result["aggregations"] >= 1

    # both survivors aggregated the dead node's deposit at some point
    assert "n2" in res[0].result["seen_peers"]
    assert "n2" in res[1].result["seen_peers"]

    # convergence: survivors agree with each other (consensus), and sit inside
    # the convex hull of the targets rather than at their own target
    w0 = np.asarray(res[0].result["final"])
    w1 = np.asarray(res[1].result["final"])
    assert np.max(np.abs(w0 - w1)) < 1.0
    for w, own in ((w0, 0.0), (w1, 1.0)):
        assert w.min() >= -0.1 and w.max() <= 2.1
    assert np.max(np.abs(w0)) > 0.05  # n0 was pulled off its own target (0.0)


# --- restart/recovery: a SIGKILL'd client resumes, not restarts --------------


def test_sigkilled_client_resumes_from_own_blob(tmp_path):
    """Crash injection + restart: the reborn process (same node_id) bootstraps
    counter and params from its own latest/ deposit instead of starting over."""
    first = run_multiprocess(
        [(_resumable_client, (str(tmp_path), "phoenix", 50),
          {"die_after_pushes": 3})],
        kill_after={0: 10.0}, join_timeout=60.0)
    assert isinstance(first[0].error, ProcessCrashed)
    assert first[0].exitcode == -signal.SIGKILL

    reborn = run_multiprocess(
        [(_resumable_client, (str(tmp_path), "phoenix", 2))], join_timeout=60.0)
    assert reborn[0].error is None, reborn[0].traceback
    r = reborn[0].result
    # the victim deposited counters 0,1,2 before the kill → resume at 3
    assert r["start_counter"] == 3
    assert r["resumed_from"] is not None and r["resumed_from"] >= 3.0
    assert r["final_counter"] == 5  # progress continued, not restarted
    # training state carried over: w kept growing from the recovered value
    assert r["w0"] > r["resumed_from"]


def test_fresh_client_under_new_id_still_starts_at_zero(tmp_path):
    run_multiprocess([(_resumable_client, (str(tmp_path), "other", 2))],
                     join_timeout=60.0)
    res = run_multiprocess([(_resumable_client, (str(tmp_path), "newborn", 1))],
                           join_timeout=60.0)
    assert res[0].error is None, res[0].traceback
    assert res[0].result["start_counter"] == 0
    assert res[0].result["resumed_from"] is None


# --- sharded gossip store across real processes ------------------------------


def test_sharded_federation_across_processes(tmp_path):
    """4 OS processes, 2 groups, nothing shared but per-group disk folders:
    every client federates within its group and hears the other group via
    gossip summaries."""
    group_map = {"n0": 0, "n1": 0, "n2": 1, "n3": 1}
    targets = {"n0": 0.0, "n1": 1.0, "n2": 3.0, "n3": 4.0}
    clients = [
        (_sharded_fed_client, (str(tmp_path), nid, group_map, 2, targets[nid]),
         dict(epochs=10))
        for nid in group_map
    ]
    res = run_multiprocess(clients, names=list(group_map), join_timeout=120.0)
    for r in res:
        assert r.error is None, r.traceback
        assert r.result["aggregations"] >= 1
    by_id = {r.node_id: r.result for r in res}
    # every client eventually saw the OTHER group's summary pseudo-peer
    for nid, g in group_map.items():
        other = 1 - g
        assert f"{GROUP_PEER_PREFIX}{other}" in by_id[nid]["seen_peers"], by_id[nid]
    # cross-group mixing actually moved weights: each final sits strictly
    # inside the global target hull, not pinned at the group's own extreme
    for nid in group_map:
        w = np.asarray(by_id[nid]["final"])
        assert w.min() >= -0.2 and w.max() <= 4.2
    assert np.asarray(by_id["n0"]["final"]).max() > 0.3  # n0 pulled off target 0


def test_run_multiprocess_cancels_unfired_kill_timers():
    """A client that finishes before its scheduled kill must not leave the
    kill timer's thread behind: the supervisor cancels outstanding timers on
    normal join, so no thread — Timer or otherwise, daemon or not — survives
    the call."""
    import threading

    before = set(threading.enumerate())
    res = run_multiprocess([(_returns_value, (21,))], kill_after={0: 300.0})
    assert res[0].error is None and res[0].result == 42
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()]
    assert not leaked, f"threads survived run_multiprocess: {leaked}"
    assert not any(isinstance(t, threading.Timer) for t in threading.enumerate())


def test_supervisor_restart_resumes_client(tmp_path):
    """The fleet worker's kill→respawn cycle at supervisor level: spawn a
    client, SIGKILL it, respawn under the same name — the second incarnation
    (same node id) resumes from the first one's deposits, and the first
    incarnation's result stays available as history."""
    from repro.core import ProcessSupervisor

    sup = ProcessSupervisor()
    try:
        sup.spawn("phoenix", _resumable_client, (str(tmp_path), "phoenix", 50),
                  {"die_after_pushes": 2})
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:  # wait for the park, then kill
            if WeightStore(DiskFolder(str(tmp_path))).pull_node("phoenix") is not None:
                break
            time.sleep(0.05)
        sup.kill("phoenix")
        sup.join(30.0)
        assert sup.result("phoenix").exitcode == -signal.SIGKILL
        assert isinstance(sup.result("phoenix").error, ProcessCrashed)

        # restart under the same name, this time without the parking kwargs
        # (exactly what the fleet worker does after an injected crash)
        sup.spawn("phoenix", _resumable_client, (str(tmp_path), "phoenix", 2))
        assert sup.incarnation("phoenix") == 1
        sup.join(60.0)
        reborn = sup.result("phoenix")
        assert reborn.error is None, reborn.traceback
        assert reborn.result["resumed_from"] is not None
        assert reborn.result["start_counter"] > 0
        # the killed incarnation's outcome is preserved as history
        assert isinstance(sup.history("phoenix")[0].error, ProcessCrashed)
    finally:
        sup.shutdown()


def test_run_multiprocess_rejects_bad_kill_index():
    with pytest.raises(ValueError):
        run_multiprocess([_returns_value], kill_after={5: 1.0})


def test_run_multiprocess_rejects_mismatched_names():
    with pytest.raises(ValueError):
        run_multiprocess([_returns_value, _returns_value], names=["only-one"])


def _sleeps_then_returns(delay, value):
    time.sleep(delay)
    return value


def test_supervisor_cancel_scheduled_kills_lets_client_finish(tmp_path):
    """The fleet worker's clean-finish path: an armed backstop SIGKILL can be
    disarmed without touching the process, so a victim that finishes cleanly
    before the timer fires completes normally — no crash, no -9 exitcode."""
    import threading

    from repro.core import ProcessSupervisor

    sup = ProcessSupervisor()
    try:
        sup.spawn("survivor", _sleeps_then_returns, (1.0, 7))
        sup.schedule_kill("survivor", 0.4)  # would land mid-sleep
        sup.cancel_scheduled_kills("survivor")
        sup.join(60.0)
        res = sup.result("survivor")
        assert res.error is None, res.traceback
        assert res.result == 7 and res.exitcode == 0
    finally:
        sup.shutdown()
    assert not any(isinstance(t, threading.Timer) and t.is_alive()
                   for t in threading.enumerate())
