import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


def tree(v):
    return {"layer": {"w": np.full((3, 3), v, np.float32)}, "step_scale": np.float32(v)}


def test_save_load_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, tree(1.0), extra={"loss": 0.5})
    params, meta = load_checkpoint(d)
    assert meta["step"] == 10 and meta["loss"] == 0.5
    assert np.allclose(params["layer"]["w"], 1.0)


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        save_checkpoint(d, s, tree(float(s)), keep=3)
    assert latest_step(d) == 5
    params, _ = load_checkpoint(d, step=5)
    assert np.allclose(params["layer"]["w"], 5.0)
    with pytest.raises(FileNotFoundError):
        load_checkpoint(d, step=0)  # garbage-collected


def test_missing_dir():
    assert latest_step("/nonexistent/ckpts") is None
    with pytest.raises(FileNotFoundError):
        load_checkpoint("/nonexistent/ckpts")
