"""Delta + cached store transport: correctness and byte accounting.

The headline property: federating with ``transport="delta"`` behind a
``CachingFolder`` produces *bitwise identical* aggregation results to the
full-blob path while reading far fewer bytes from the shared folder.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    AsyncFederatedNode,
    CachingFolder,
    DiskFolder,
    InMemoryFolder,
    NodeUpdate,
    WeightStore,
    deserialize_update_delta,
    make_folder,
    peek_meta,
    serialize_update,
    serialize_update_delta,
)
from repro.core.serialize import DeltaBaseMismatch, content_hash, delta_density
from repro.core.strategies import FedAvg


def _params(rng, scale=1.0):
    # Big enough that payload bytes dominate npz container overhead — the
    # regime transport choices are about.
    return {
        "layer": {"w": (scale * rng.normal(size=(256, 128))).astype(np.float32)},
        "head": (scale * rng.normal(size=(512,))).astype(np.float32),
    }


def _sparse_step(params, rng, fraction=0.01):
    """Deterministically mutate a small fraction of entries in-place-ish."""
    out = {}
    for top, v in params.items():
        if isinstance(v, dict):
            out[top] = {k: a.copy() for k, a in v.items()}
        else:
            out[top] = v.copy()
    for arr in [out["layer"]["w"], out["head"]]:
        flat = arr.reshape(-1)
        n = max(1, int(fraction * flat.size))
        idx = rng.choice(flat.size, size=n, replace=False)
        flat[idx] += rng.normal(size=n).astype(np.float32)
    return out


# --- delta wire format ------------------------------------------------------


def test_delta_roundtrip_is_bitwise_exact():
    rng = np.random.default_rng(0)
    base = _params(rng)
    base_blob = serialize_update(NodeUpdate(base, num_examples=1, node_id="n", counter=0))
    new = _sparse_step(base, rng)
    u = NodeUpdate(new, num_examples=9, node_id="n", counter=1, timestamp=2.5,
                   metrics={"loss": 0.25})
    blob = serialize_update_delta(u, base, content_hash(base_blob))
    u2 = deserialize_update_delta(blob, base)
    assert np.array_equal(u2.params["layer"]["w"], new["layer"]["w"])
    assert np.array_equal(u2.params["head"], new["head"])
    assert (u2.num_examples, u2.counter, u2.timestamp) == (9, 1, 2.5)
    assert u2.metrics == {"loss": 0.25}
    assert peek_meta(blob)["delta_of"] == content_hash(base_blob)


def test_delta_blob_is_smaller_for_sparse_changes():
    rng = np.random.default_rng(1)
    base = _params(rng)
    new = _sparse_step(base, rng, fraction=0.01)
    u = NodeUpdate(new, num_examples=1, node_id="n", counter=1)
    full = serialize_update(u)
    delta = serialize_update_delta(u, base, "h")
    assert len(delta) < 0.5 * len(full)


def test_delta_dense_fallback_and_density():
    rng = np.random.default_rng(2)
    base = _params(rng)
    totally_new = _params(np.random.default_rng(3))
    assert delta_density(totally_new, base) > 0.9
    u = NodeUpdate(totally_new, num_examples=1, node_id="n", counter=1)
    blob = serialize_update_delta(u, base, "h")  # every leaf goes dense
    u2 = deserialize_update_delta(blob, base)
    assert np.array_equal(u2.params["layer"]["w"], totally_new["layer"]["w"])


def test_delta_structural_mismatch_raises():
    rng = np.random.default_rng(4)
    base = _params(rng)
    other = {"different": np.ones((3,), np.float32)}
    u = NodeUpdate(other, num_examples=1, node_id="n", counter=1)
    with pytest.raises(ValueError):
        serialize_update_delta(u, base, "h")


def test_delta_quantized_is_close_not_exact():
    rng = np.random.default_rng(5)
    base = _params(rng)
    new = _sparse_step(base, rng, fraction=0.05)
    u = NodeUpdate(new, num_examples=1, node_id="n", counter=1)
    u2 = deserialize_update_delta(serialize_update_delta(u, base, "h", quantize=True), base)
    w, w2 = new["layer"]["w"], u2.params["layer"]["w"]
    assert not np.array_equal(w, w2) or np.array_equal(w, base["layer"]["w"])
    changed = w != base["layer"]["w"]
    assert np.max(np.abs((w - w2)[changed])) <= np.abs(w[changed]).max() / 127.0 + 1e-6


def test_delta_bfloat16_roundtrip():
    base = {"w": jnp.asarray(np.linspace(-1, 1, 32), jnp.bfloat16)}
    new = {"w": np.asarray(base["w"]).copy()}
    new["w"][3] = np.float32(0.625)  # exactly representable in bfloat16
    u = NodeUpdate(new, num_examples=1, node_id="b", counter=1)
    u2 = deserialize_update_delta(serialize_update_delta(u, base, "h"), base)
    assert u2.params["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(u2.params["w"], np.float32),
                          np.asarray(new["w"], np.float32))


# --- CachingFolder ----------------------------------------------------------


@pytest.mark.parametrize("inner_factory", ["memory", "disk"])
def test_caching_folder_hits_and_invalidation(inner_factory, tmp_path):
    inner = InMemoryFolder() if inner_factory == "memory" else DiskFolder(str(tmp_path))
    folder = CachingFolder(inner)
    folder.put("k", b"abc")
    assert folder.get("k") == b"abc"          # first read populates the cache
    assert folder.misses == 1 and folder.bytes_fetched == 3
    assert folder.get("k") == b"abc"          # second read is a hit
    assert folder.hits == 1 and folder.bytes_saved == 3
    inner.put("k", b"defg")                    # out-of-band overwrite
    assert folder.get("k") == b"defg"          # version changed → refetch
    assert folder.bytes_fetched == 7
    assert folder.get("k") == b"defg"          # now cached again
    assert folder.hits == 2
    folder.put("k", b"hi")                     # own put invalidates, not caches
    assert folder.get("k") == b"hi"
    assert folder.bytes_fetched == 9
    folder.delete("k")
    assert folder.get("k") is None


def test_caching_folder_second_reader_sees_writes(tmp_path):
    writer = DiskFolder(str(tmp_path))
    reader = CachingFolder(DiskFolder(str(tmp_path)))
    writer.put("x", b"one")
    assert reader.get("x") == b"one"
    writer.put("x", b"two")
    assert reader.get("x") == b"two"  # never a stale hit
    stats = reader.cache_stats()
    assert stats["misses"] == 2 and stats["bytes_fetched"] == 6


def test_make_folder_cache_prefix(tmp_path):
    f = make_folder(f"cache+{tmp_path}/store")
    assert isinstance(f, CachingFolder) and isinstance(f.inner, DiskFolder)
    assert isinstance(make_folder("cache+memory://"), CachingFolder)


# --- WeightStore decoded-update cache ----------------------------------------


@pytest.mark.parametrize("inner_factory", ["memory", "disk"])
def test_weightstore_pull_skips_decode_for_unchanged_peers(inner_factory, tmp_path):
    """The decode-side twin of CachingFolder: a peer whose deposit carries an
    unchanged version token is served from the decoded-update cache — no npz
    decode, exact counts asserted."""
    folder = InMemoryFolder() if inner_factory == "memory" else DiskFolder(str(tmp_path))
    writer = WeightStore(folder)
    reader = WeightStore(folder)
    rng = np.random.default_rng(11)
    p1, p2 = _params(rng), _params(rng)
    writer.push(NodeUpdate(p1, num_examples=1, node_id="n1", counter=0))
    writer.push(NodeUpdate(p2, num_examples=1, node_id="n2", counter=0))

    assert len(reader.pull()) == 2
    assert (reader.decode_misses, reader.decode_hits) == (2, 0)
    assert len(reader.pull()) == 2          # nothing changed: all hits
    assert (reader.decode_misses, reader.decode_hits) == (2, 2)

    writer.push(NodeUpdate(_sparse_step(p1, rng), num_examples=1, node_id="n1", counter=1))
    pulled = {u.node_id: u for u in reader.pull()}
    assert pulled["n1"].counter == 1        # fresh blob was decoded, not stale-served
    assert (reader.decode_misses, reader.decode_hits) == (3, 3)  # n1 miss, n2 hit


def test_weightstore_decode_cache_behind_caching_folder(tmp_path):
    """Stacked fast paths: CachingFolder skips the download, the decode cache
    skips the npz decode — the second pull costs neither."""
    disk = DiskFolder(str(tmp_path))
    cached = CachingFolder(disk)
    writer = WeightStore(disk)
    reader = WeightStore(cached)
    rng = np.random.default_rng(12)
    writer.push(NodeUpdate(_params(rng), num_examples=1, node_id="n", counter=0))
    assert len(reader.pull()) == 1
    fetched = cached.bytes_fetched
    assert len(reader.pull()) == 1
    assert reader.decode_hits == 1
    assert cached.bytes_fetched == fetched  # decode hit never even touched get()


def test_weightstore_decode_cache_is_bounded():
    folder = InMemoryFolder()
    store = WeightStore(folder, decode_cache_entries=2)
    rng = np.random.default_rng(13)
    for i in range(5):
        store.push(NodeUpdate(_params(rng), num_examples=1, node_id=f"n{i}", counter=0))
    store.pull()
    assert len(store._decoded_latest) == 2  # LRU-bounded, not fleet-sized
    store.clear()
    assert len(store._decoded_latest) == 0


def test_weightstore_decode_cache_disabled():
    folder = InMemoryFolder()
    store = WeightStore(folder, decode_cache_entries=0)
    store.push(NodeUpdate({"w": np.ones((3,), np.float32)}, num_examples=1,
                          node_id="n", counter=0))
    store.pull()
    store.pull()
    assert store.decode_hits == 0


# --- WeightStore delta transport --------------------------------------------


def test_weightstore_delta_rebases_and_gcs_old_bases(tmp_path):
    folder = DiskFolder(str(tmp_path))
    store = WeightStore(folder, transport="delta", rebase_every=3)
    rng = np.random.default_rng(6)
    params = _params(rng)
    for ctr in range(8):
        params = _sparse_step(params, rng)
        store.push(NodeUpdate(params, num_examples=1, node_id="n", counter=ctr))
    base_keys = [k for k in folder.keys() if k.startswith("base/n/")]
    assert len(base_keys) == 1  # old bases were garbage collected
    pulled = WeightStore(folder).pull_node("n")  # a fresh reader, any transport
    assert pulled.counter == 7
    assert np.array_equal(pulled.params["layer"]["w"], params["layer"]["w"])


def test_weightstore_transport_validation():
    with pytest.raises(ValueError):
        WeightStore(InMemoryFolder(), transport="gzip")
    with pytest.raises(ValueError):
        AsyncFederatedNode(store=WeightStore(InMemoryFolder()), transport="delta")


def test_delta_base_mismatch_reports_leaf():
    rng = np.random.default_rng(7)
    base = _params(rng)
    u = NodeUpdate(_sparse_step(base, rng), num_examples=1, node_id="n", counter=1)
    blob = serialize_update_delta(u, base, "h")
    with pytest.raises((DeltaBaseMismatch, KeyError, ValueError)):
        deserialize_update_delta(blob, {"other": np.zeros((2,), np.float32)})


# --- the acceptance property: bitwise-equal results, fewer bytes ------------


def _run_federation(base_dir, transport, *, adopt, rounds=6, num_nodes=3):
    """Deterministic sequential async federation; every node reads the shared
    DiskFolder through its own CachingFolder (its private cache, as a real
    client on a real mount would). Returns every aggregation result each node
    ever produced, plus total bytes read from the folder.

    ``adopt=False`` is the partial-fine-tuning regime (LoRA-style: pushed
    params evolve by sparse local steps; the global aggregate is tracked but
    not folded back) — the regime where delta encoding pays off. With
    ``adopt=True`` the weighted mean perturbs every entry, deltas go dense,
    and the store falls back to rebasing — correct, just not smaller.
    """
    folders = [CachingFolder(DiskFolder(base_dir)) for _ in range(num_nodes)]
    nodes = [
        AsyncFederatedNode(strategy=FedAvg(), shared_folder=folders[i],
                           node_id=f"n{i}", transport=transport)
        for i in range(num_nodes)
    ]
    rngs = [np.random.default_rng(100 + i) for i in range(num_nodes)]
    params = [_params(np.random.default_rng(42)) for _ in range(num_nodes)]  # common init
    aggregates = []
    for _ in range(rounds):
        for i in range(num_nodes):
            params[i] = _sparse_step(params[i], rngs[i])
            aggregated = nodes[i].update_parameters(params[i], num_examples=10)
            if aggregated is not None:
                aggregates.append(aggregated)
                if adopt:
                    params[i] = aggregated
    bytes_read = sum(f.bytes_fetched for f in folders)
    return aggregates, bytes_read


def test_delta_cached_transport_matches_full_bitwise_with_fewer_bytes(tmp_path):
    full_aggs, full_bytes = _run_federation(str(tmp_path / "full"), "full", adopt=False)
    delta_aggs, delta_bytes = _run_federation(str(tmp_path / "delta"), "delta", adopt=False)
    # identical schedule → bitwise identical aggregation results, every time
    assert len(full_aggs) == len(delta_aggs) > 0
    for pf, pd in zip(full_aggs, delta_aggs):
        assert np.array_equal(pf["layer"]["w"], pd["layer"]["w"])
        assert np.array_equal(pf["head"], pd["head"])
    # ... while reading measurably fewer bytes from the shared folder
    assert delta_bytes < 0.5 * full_bytes, (delta_bytes, full_bytes)


def test_delta_transport_stays_bitwise_exact_when_aggregates_are_adopted(tmp_path):
    """Adopting the aggregate densifies every delta (forced rebases); results
    must still match the full-blob path bitwise."""
    full_aggs, _ = _run_federation(str(tmp_path / "full"), "full", adopt=True, rounds=4)
    delta_aggs, _ = _run_federation(str(tmp_path / "delta"), "delta", adopt=True, rounds=4)
    assert len(full_aggs) == len(delta_aggs) > 0
    for pf, pd in zip(full_aggs, delta_aggs):
        assert np.array_equal(pf["layer"]["w"], pd["layer"]["w"])
        assert np.array_equal(pf["head"], pd["head"])


def test_weightstore_delta_hostile_node_ids_base_gc(tmp_path):
    """Base GC must not cross node borders when ids contain '/'."""
    folder = DiskFolder(str(tmp_path))
    rng = np.random.default_rng(8)
    params = {nid: _params(np.random.default_rng(9)) for nid in ("team", "team/alpha")}
    store = WeightStore(folder, transport="delta", rebase_every=2)
    for ctr in range(5):  # rebase_every=2 → multiple rebases per node
        for nid in params:
            params[nid] = _sparse_step(params[nid], rng)
            store.push(NodeUpdate(params[nid], num_examples=1, node_id=nid, counter=ctr))
    for nid in params:
        bases = [k for k in folder.keys() if k.rpartition("/")[0] == f"base/{nid}"]
        assert len(bases) == 1, (nid, bases)
        pulled = WeightStore(folder).pull_node(nid)
        assert pulled.counter == 4
        assert np.array_equal(pulled.params["layer"]["w"], params[nid]["layer"]["w"])
    assert sorted(store.node_ids()) == ["team", "team/alpha"]


def test_async_skip_check_survives_delta_rebase(tmp_path):
    """A node's own rebase writes base/<node>/<hash>; that must not defeat its
    own state-hash skip check (the whole point of Algorithm 1's fast path)."""
    folder = DiskFolder(str(tmp_path))
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id="solo", transport="delta")
    node.store.rebase_every = 1  # force a rebase (base churn) on every push
    rng = np.random.default_rng(10)
    p = _params(rng)
    assert node.update_parameters(p, num_examples=1) is None
    pulls_before = node.num_pulls
    for _ in range(3):
        p = _sparse_step(p, rng)
        assert node.update_parameters(p, num_examples=1) is None
    assert node.num_pulls == pulls_before  # all skipped via the hash check
    assert node.num_skipped_pulls >= 3


def test_diskfolder_state_hash_changes_on_same_size_rewrite(tmp_path):
    """Same content, same size, potentially same mtime tick — the hash must
    still move (fresh-inode hardening), or peers' updates get skipped."""
    folder = DiskFolder(str(tmp_path))
    folder.put("latest/a", b"same-bytes")
    h1 = folder.state_hash()
    folder.put("latest/a", b"same-bytes")
    assert folder.state_hash() != h1


# --- the composable pipeline: spec grammar --------------------------------


def _pipe():
    from repro.core import normalize_transport
    return normalize_transport


def test_pipeline_spec_grammar_and_legacy_mapping():
    from repro.core import normalize_transport, parse_folder_uri

    # all five legacy names map onto pipeline specs
    assert normalize_transport("full") == "full"
    assert normalize_transport("quantized") == "quantized"
    assert normalize_transport("delta") == "delta"
    assert normalize_transport("delta_q") == "delta(q)"
    assert normalize_transport("topk") == "topk"
    assert normalize_transport(None) == "full"
    assert normalize_transport(None, quantized=True) == "quantized"
    # compress= appends the envelope stage
    assert normalize_transport("delta", compress="npz") == "delta|npz"
    # explicit pipeline specs canonicalize deterministically
    assert normalize_transport("topk|delta") == "topk"
    assert normalize_transport("delta(chain=4)") == "delta(chain=4)"
    assert normalize_transport("topk(adaptive)") == "topk(adaptive)"
    assert normalize_transport("delta(chain=1)") == "delta"
    # the folder-URI side of the grammar is the same parser family
    wrappers, base = parse_folder_uri("shard8+cache+/mnt/x")
    assert wrappers == [("shard", {"groups": 8, "levels": 1}), ("cache", {})]
    assert base == "/mnt/x"
    # the x<L> extension selects hierarchical summary tiers
    wrappers, base = parse_folder_uri("shard64x2+/mnt/x")
    assert wrappers == [("shard", {"groups": 64, "levels": 2})]
    assert base == "/mnt/x"
    assert parse_folder_uri("memory://") == ([], "memory://")


def test_pipeline_spec_rejects_garbage():
    from repro.core import normalize_transport

    for bad in ("gzip", "delta(chain=0)", "delta(q,chain=2)", "npz|delta",
                "delta|npz|zstd", "full(x=1)", "topk(fraction=2.0)",
                "delta(wat=1)", "full|delta", "topk|delta(chain=2)", ""):
        with pytest.raises(ValueError):
            normalize_transport(bad)
    with pytest.raises(ValueError):
        WeightStore(InMemoryFolder(), transport="delta", compress="gzip")


def test_store_and_nodes_accept_pipeline_specs(tmp_path):
    """A full spec string flows through WeightStore, AsyncFederatedNode, and
    ShardedWeightStore; node-vs-store agreement compares canonical specs, so
    'delta_q' matches a 'delta(q)' store."""
    from repro.core.gossip import ShardedWeightStore

    store = WeightStore(InMemoryFolder(), transport="delta(chain=3)|npz")
    assert store.transport == "delta(chain=3)|npz"
    assert store.compress == "npz"
    AsyncFederatedNode(store=WeightStore(InMemoryFolder(), transport="delta_q"),
                       transport="delta(q)")  # canonical match: no raise
    sharded = ShardedWeightStore("shard2+memory://", transport="delta(chain=2)")
    rng = np.random.default_rng(0)
    for i in range(4):
        sharded.push(NodeUpdate(_params(rng), num_examples=1,
                                node_id=f"n{i}", counter=0))
    assert len(sharded.pull()) == 4
    with pytest.raises(ValueError):
        ShardedWeightStore("shard2+memory://", transport="gzip")


# --- delta chains ----------------------------------------------------------


def _chain_depth_of(folder, node="n"):
    """Reconstruction depth the current latest blob advertises: 0 = full,
    1 = plain delta (no chain_depth meta), else the chain_depth meta."""
    from repro.core.serialize import maybe_decompress

    meta = peek_meta(maybe_decompress(folder.get(f"latest/{node}")))
    if "delta_of" not in meta:
        return 0
    return int(meta.get("chain_depth", 1))


def _step(params, rng, kind):
    """One adversarial local step: sparse drift, a dense rewrite (forces the
    writer's rebase guard), a single-entry tweak, or a no-op re-push."""
    if kind == "same":
        return {k: (dict(v) if isinstance(v, dict) else v) for k, v in params.items()}
    if kind == "dense":
        return _params(rng)
    return _sparse_step(params, rng, fraction=0.02 if kind == "sparse" else 0.0005)


from _hyp import given, settings, strategies as hyp_st  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(
    chain=hyp_st.integers(1, 4),
    rebase=hyp_st.integers(3, 9),
    kinds=hyp_st.lists(hyp_st.sampled_from(["sparse", "dense", "tiny", "same"]),
                       min_size=4, max_size=12),
    seed=hyp_st.integers(0, 2**16),
)
def test_delta_chain_reconstructs_bitwise_through_bounded_hops(chain, rebase, kinds, seed):
    """The chain-transport contract, under adversarial push orderings: after
    EVERY push, (a) a fresh reader and a steady reader both reconstruct the
    pushed params bit-exactly, (b) the advertised reconstruction depth never
    exceeds ``chain``, and (c) re-anchoring fires exactly at the bound — a
    depth-``chain`` blob is followed by depth 1 (re-anchor) or 0 (rebase)."""
    rng = np.random.default_rng(seed)
    folder = InMemoryFolder()
    store = WeightStore(folder, transport=f"delta(chain={chain})",
                        rebase_every=rebase)
    steady = WeightStore(folder)
    params = _params(rng)
    depths = []
    for ctr, kind in enumerate(kinds):
        params = _step(params, rng, kind)
        store.push(NodeUpdate(params, num_examples=1, node_id="n", counter=ctr))
        depth = _chain_depth_of(folder)
        assert depth <= chain, (depths, depth)
        depths.append(depth)
        for reader in (WeightStore(folder), steady):  # fresh + steady
            got = reader.pull_node("n")
            assert got is not None and got.counter == ctr
            np.testing.assert_array_equal(got.params["layer"]["w"],
                                          params["layer"]["w"])
            np.testing.assert_array_equal(got.params["head"], params["head"])
    for prev, nxt in zip(depths, depths[1:]):
        if prev == chain:       # bound hit → re-anchor (or a full rebase)
            assert nxt in (0, 1), depths
        elif prev > 0 and nxt not in (0, 1):
            assert nxt == prev + 1, depths  # links deepen one hop at a time
    # chain links are GC'd as segments retire: never more than the current
    # segment's referencable links, and exactly one base
    chain_keys = [k for k in folder.keys() if k.startswith("chain/")]
    base_keys = [k for k in folder.keys() if k.startswith("base/")]
    assert len(chain_keys) <= max(chain - 1, 0) and len(base_keys) == 1


def test_delta_chain_wire_bytes_strictly_below_plain_delta():
    """The point of chains: per-push bytes track one step's sparsity instead
    of the drift accumulated since the base. Same sparse-step schedule, same
    rebase cadence → chain=4 moves strictly fewer bytes (writer deposits +
    steady-reader reads) than plain delta."""
    wire = {}
    for transport in ("delta", "delta(chain=4)"):
        rng = np.random.default_rng(42)
        folder = InMemoryFolder()
        writer = WeightStore(folder, transport=transport, rebase_every=50)
        reader = WeightStore(folder)
        params = _params(rng)
        for ctr in range(12):
            params = _sparse_step(params, rng, fraction=0.005)
            writer.push(NodeUpdate(params, num_examples=1, node_id="n", counter=ctr))
            got = reader.pull_node("n")
            np.testing.assert_array_equal(got.params["head"], params["head"])
        wire[transport] = writer.bytes_written + reader.bytes_read
    assert wire["delta(chain=4)"] < wire["delta"], wire


def test_async_skip_check_survives_chain_links(tmp_path):
    """A node's own chain/ deposits (like its base/ rebases) must not defeat
    its own state-hash skip check."""
    folder = DiskFolder(str(tmp_path))
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id="solo", transport="delta(chain=3)")
    rng = np.random.default_rng(3)
    p = _params(rng)
    assert node.update_parameters(p, num_examples=1) is None
    pulls_before = node.num_pulls
    for _ in range(4):
        p = _sparse_step(p, rng)
        assert node.update_parameters(p, num_examples=1) is None
    assert node.num_pulls == pulls_before
    assert node.num_skipped_pulls >= 4


def test_chain_federation_matches_full_bitwise(tmp_path):
    """End-to-end: a chained-delta federation produces bitwise-identical
    aggregates to the full-blob path (the PR-3 equivalence bar, extended to
    the new codec)."""
    full_aggs, _ = _run_federation(str(tmp_path / "full"), "full", adopt=False)
    chain_aggs, _ = _run_federation(str(tmp_path / "chain"), "delta(chain=3)",
                                    adopt=False)
    assert len(full_aggs) == len(chain_aggs) > 0
    for pf, pc in zip(full_aggs, chain_aggs):
        assert np.array_equal(pf["layer"]["w"], pc["layer"]["w"])
        assert np.array_equal(pf["head"], pc["head"])


# --- background prefetch ----------------------------------------------------


def test_warm_cache_prefetches_stale_peers():
    folder = InMemoryFolder()
    writer = WeightStore(folder)
    reader = WeightStore(folder)
    rng = np.random.default_rng(5)
    for i in range(3):
        writer.push(NodeUpdate(_params(rng), num_examples=1,
                               node_id=f"n{i}", counter=0))
    assert reader.warm_cache() == 3
    assert reader.warm_cache() == 0        # second sweep: everything warm
    assert len(reader.pull()) == 3
    stats = reader.transport_stats()
    assert stats["decode_hits"] == 3       # the pull paid zero decodes
    assert stats["prefetched"] == 3 and stats["prefetch_cycles"] == 2
    # warm_cache(exclude=...) skips the owner's own deposit
    assert reader.warm_cache(exclude="n0") == 0


def test_prefetch_thread_warms_between_steps():
    import time as _time

    folder = InMemoryFolder()
    writer = WeightStore(folder)
    reader = WeightStore(folder)
    handle = reader.start_prefetch(0.005)
    try:
        rng = np.random.default_rng(6)
        writer.push(NodeUpdate(_params(rng), num_examples=1, node_id="p", counter=0))
        deadline = _time.monotonic() + 5.0
        while reader.transport_stats()["prefetched"] < 1:
            assert _time.monotonic() < deadline, "prefetcher never warmed the cache"
            _time.sleep(0.01)
        misses_before = reader.decode_misses
        assert len(reader.pull()) == 1
        assert reader.decode_misses == misses_before  # pull was all hits
    finally:
        reader.stop_prefetch()
    assert not handle.running


def test_node_prefetch_kwarg_wires_through():
    folder = InMemoryFolder()
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id="a", prefetch_interval=0.005)
    try:
        assert node.store._prefetcher is not None and node.store._prefetcher.running
        assert node.store._prefetcher.exclude == "a"
    finally:
        node.store.stop_prefetch()


# --- adaptive top-k ----------------------------------------------------------


def test_adaptive_topk_scales_k_with_residual_norm():
    """topk(adaptive): a burst of change (residual norm spiking above its
    running mean) ships more entries than the steady state; quiet stretches
    ship fewer than the configured fraction."""
    N = 20_000
    store = WeightStore(InMemoryFolder(), transport="topk(adaptive)",
                        topk_fraction=0.01, rebase_every=1000)
    rng = np.random.default_rng(7)
    cur = np.zeros((N,), np.float32)
    store.push(NodeUpdate({"w": cur}, num_examples=1, node_id="n", counter=0))
    steady_k = None
    for ctr in range(1, 6):
        cur = cur.copy()
        cur[rng.choice(N, 50, replace=False)] += 0.1
        store.push(NodeUpdate({"w": cur}, num_examples=1, node_id="n", counter=ctr))
        steady_k = store.pipeline.stats.topk_k
    assert steady_k < int(0.01 * N)  # quiet regime: below the base fraction
    cur = cur + rng.normal(size=N).astype(np.float32)  # dense burst
    store.push(NodeUpdate({"w": cur}, num_examples=1, node_id="n", counter=99))
    burst_k = store.pipeline.stats.topk_k
    assert burst_k > steady_k
    assert store.pipeline.stats.topk_fraction_effective > 0.01
    assert store.pipeline.stats.residual_norm > 0.0


def test_adaptive_topk_error_feedback_still_drains():
    """Adaptivity must not break the error-feedback contract: repeatedly
    pushing the same target converges readers to it exactly."""
    store = WeightStore(InMemoryFolder(), transport="topk(adaptive)",
                        topk_fraction=0.25, rebase_every=1000)
    target = {"w": np.linspace(-2, 2, 4096).astype(np.float32)}
    store.push(NodeUpdate({"w": np.zeros((4096,), np.float32)},
                          num_examples=1, node_id="n", counter=0))
    for ctr in range(1, 40):
        store.push(NodeUpdate(target, num_examples=1, node_id="n", counter=ctr))
    pulled = WeightStore(store.folder).pull_node("n")
    np.testing.assert_array_equal(pulled.params["w"], target["w"])


# --- strategy-state recovery blobs -------------------------------------------


@pytest.mark.parametrize("strategy_name", ["fedavgm", "fedadam"])
def test_strategy_state_survives_restart(strategy_name, tmp_path):
    """A resumed node restores its server-optimizer state (momentum/moments)
    from the state/ blob, so its next aggregation continues the trajectory
    instead of starting cold."""
    from repro.core.strategies import get_strategy

    folder = DiskFolder(str(tmp_path))
    mk = lambda: get_strategy(strategy_name, server_lr=0.5)
    a = AsyncFederatedNode(strategy=mk(), shared_folder=folder, node_id="a",
                           persist_strategy_state=True)
    b = AsyncFederatedNode(strategy=mk(), shared_folder=folder, node_id="b",
                           persist_strategy_state=True)
    rng = np.random.default_rng(8)
    pa, pb = _params(rng), _params(rng)
    a.update_parameters(pa, num_examples=1)
    b.update_parameters(pb, num_examples=1)
    assert a.update_parameters(pa, num_examples=1) is not None
    ref = {k: v.copy() for k, v in a.strategy.state_dict().items()}
    # crash + restart under the same id: state restored bit-exactly
    a2 = AsyncFederatedNode(strategy=mk(), shared_folder=folder, node_id="a",
                            persist_strategy_state=True)
    assert a2.resumed is not None
    restored = a2.strategy.state_dict()
    assert restored is not None and set(restored) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(restored[k], ref[k], err_msg=k)
    # and the restored node aggregates without error
    pb2 = _sparse_step(pb, rng)
    b.update_parameters(pb2, num_examples=1)
    assert a2.update_parameters(pa, num_examples=1) is not None


def test_stateless_strategy_persists_nothing():
    folder = InMemoryFolder()
    a = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="a",
                           persist_strategy_state=True)
    b = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="b")
    a.update_parameters({"w": np.ones((4,), np.float32)}, num_examples=1)
    b.update_parameters({"w": np.zeros((4,), np.float32)}, num_examples=1)
    a.update_parameters({"w": np.ones((4,), np.float32)}, num_examples=1)
    assert not [k for k in folder.keys() if k.startswith("state/")]


def test_state_blobs_do_not_defeat_skip_checks():
    """state/ deposits are recovery data, not federation signal: they are
    excluded from every node's state hash, so a peer persisting its optimizer
    state must not trigger redundant re-pulls fleet-wide."""
    from repro.core.strategies import FedAvgM

    folder = InMemoryFolder()
    a = AsyncFederatedNode(strategy=FedAvgM(), shared_folder=folder, node_id="a",
                           persist_strategy_state=True)
    b = AsyncFederatedNode(strategy=FedAvgM(), shared_folder=folder, node_id="b",
                           persist_strategy_state=True)
    p = {"w": np.ones((8,), np.float32)}
    a.update_parameters(p, num_examples=1)
    b.update_parameters(p, num_examples=1)          # b aggregates + persists
    assert a.update_parameters(p, num_examples=1) is not None  # a folds b in
    skipped = a.num_skipped_pulls
    # nothing but a's own pushes (and state blobs) changes now → all skips
    for _ in range(3):
        assert a.update_parameters(p, num_examples=1) is None
    assert a.num_skipped_pulls == skipped + 3


def test_node_transport_matches_store_with_compress_envelope():
    """Regression: a node asserting the legacy wire policy must accept a
    store that folded a compress= envelope into its canonical spec — the
    envelope is a store-construction detail, not a policy disagreement."""
    store = WeightStore(InMemoryFolder(), transport="delta", compress="npz")
    AsyncFederatedNode(store=store, transport="delta")        # no raise
    AsyncFederatedNode(store=store, transport="delta|npz")    # exact: no raise
    with pytest.raises(ValueError):
        AsyncFederatedNode(store=store, transport="full")


def test_prefetcher_does_not_pin_its_store():
    """The prefetch thread must hold only a weak reference: a short-lived
    store that was never stop_prefetch()-ed stays collectable (its caches
    hold model-sized decoded vectors) and the poller exits on its own."""
    import gc
    import weakref

    store = WeightStore(InMemoryFolder(), prefetch_interval=0.01)
    ref = weakref.ref(store)
    handle = store._prefetcher
    del store
    gc.collect()
    assert ref() is None, "prefetch thread kept the store alive"
    handle._thread.join(timeout=5.0)
    assert not handle.running


# --- retry+ folder wrapper (flaky-store hardening) ---------------------------


def test_parse_folder_uri_retry_wrapper():
    from repro.core import parse_folder_uri

    assert parse_folder_uri("retry+/mnt/x") == ([("retry", {})], "/mnt/x")
    wrappers, base = parse_folder_uri("retry+cache+/mnt/x")
    assert wrappers == [("retry", {}), ("cache", {})] and base == "/mnt/x"
    wrappers, base = parse_folder_uri("shard4+retry+cache+/mnt/x")
    assert wrappers == [("shard", {"groups": 4, "levels": 1}),
                        ("retry", {}), ("cache", {})]


def test_make_folder_retry_composition(tmp_path):
    from repro.core import CachingFolder, DiskFolder, RetryFolder, make_folder

    f = make_folder(f"retry+{tmp_path}")
    assert isinstance(f, RetryFolder) and isinstance(f.inner, DiskFolder)
    # leftmost prefix is the outermost wrapper: retries wrap the cache's
    # misses, a cached hit never pays the retry machinery
    rc = make_folder(f"retry+cache+{tmp_path}")
    assert isinstance(rc, RetryFolder) and isinstance(rc.inner, CachingFolder)
    cr = make_folder(f"cache+retry+{tmp_path}")
    assert isinstance(cr, CachingFolder) and isinstance(cr.inner, RetryFolder)
    rc.put("k", b"v")
    assert rc.get("k") == b"v" and cr.get("k") == b"v"


class _FlakyFolder:
    """SharedFolder test double that fails the first N calls per method with
    a transient OSError, then behaves."""

    def __init__(self, inner, failures=2):
        self.inner = inner
        self._left = {}
        self._failures = failures
        self.calls = 0

    def _maybe_fail(self, op):
        self.calls += 1
        left = self._left.setdefault(op, self._failures)
        if left > 0:
            self._left[op] = left - 1
            raise OSError(f"transient {op} failure")

    def get(self, key):
        self._maybe_fail("get")
        return self.inner.get(key)

    def put(self, key, data):
        self._maybe_fail("put")
        return self.inner.put(key, data)

    def keys(self):
        self._maybe_fail("keys")
        return self.inner.keys()

    def delete(self, key):
        self._maybe_fail("delete")
        return self.inner.delete(key)

    def version(self, key):
        return self.inner.version(key)

    def state_hash(self, exclude=None):
        return self.inner.state_hash(exclude=exclude)

    def put_if_absent(self, key, data):
        return self.inner.put_if_absent(key, data)


def test_retry_folder_rides_out_transient_faults():
    from repro.core import InMemoryFolder, RetryFolder
    from repro.core.store import folder_retries

    flaky = _FlakyFolder(InMemoryFolder(), failures=2)
    folder = RetryFolder(flaky, attempts=4, base_delay=0.01, max_delay=0.05)
    folder.put("k", b"v")           # 2 transient put failures absorbed
    assert folder.get("k") == b"v"  # 2 transient get failures absorbed
    assert "k" in folder.keys()
    assert folder.retries == 6
    assert folder_retries(folder) == 6


def test_retry_folder_gives_up_after_attempts():
    from repro.core import InMemoryFolder, RetryFolder

    flaky = _FlakyFolder(InMemoryFolder(), failures=99)
    folder = RetryFolder(flaky, attempts=3, base_delay=0.01, max_delay=0.02)
    with pytest.raises(OSError):
        folder.get("missing")
    assert folder.retries == 2  # attempts-1 retries, then the error surfaces


def test_retry_folder_put_if_absent_is_single_shot():
    """CAS must not retry: a timeout whose first attempt actually landed
    would turn 'exactly one winner' into 'nobody knows'. The call passes
    through once and any failure surfaces immediately."""
    from repro.core import InMemoryFolder, RetryFolder

    inner = InMemoryFolder()
    folder = RetryFolder(inner, attempts=4, base_delay=0.01)
    assert folder.put_if_absent("k", b"first") is True
    assert folder.put_if_absent("k", b"second") is False
    assert inner.get("k") == b"first"
    assert folder.retries == 0


def test_retry_counter_flows_into_transport_stats():
    from repro.core import InMemoryFolder, NodeUpdate, RetryFolder, WeightStore

    flaky = _FlakyFolder(InMemoryFolder(), failures=1)
    store = WeightStore(RetryFolder(flaky, attempts=3, base_delay=0.01))
    store.push(NodeUpdate({"w": np.ones(4, np.float32)}, num_examples=1,
                          node_id="n0", counter=0))
    stats = store.transport_stats()
    assert stats["folder_retries"] >= 1
