"""Delta + cached store transport: correctness and byte accounting.

The headline property: federating with ``transport="delta"`` behind a
``CachingFolder`` produces *bitwise identical* aggregation results to the
full-blob path while reading far fewer bytes from the shared folder.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    AsyncFederatedNode,
    CachingFolder,
    DiskFolder,
    InMemoryFolder,
    NodeUpdate,
    WeightStore,
    deserialize_update_delta,
    make_folder,
    peek_meta,
    serialize_update,
    serialize_update_delta,
)
from repro.core.serialize import DeltaBaseMismatch, content_hash, delta_density
from repro.core.strategies import FedAvg


def _params(rng, scale=1.0):
    # Big enough that payload bytes dominate npz container overhead — the
    # regime transport choices are about.
    return {
        "layer": {"w": (scale * rng.normal(size=(256, 128))).astype(np.float32)},
        "head": (scale * rng.normal(size=(512,))).astype(np.float32),
    }


def _sparse_step(params, rng, fraction=0.01):
    """Deterministically mutate a small fraction of entries in-place-ish."""
    out = {}
    for top, v in params.items():
        if isinstance(v, dict):
            out[top] = {k: a.copy() for k, a in v.items()}
        else:
            out[top] = v.copy()
    for arr in [out["layer"]["w"], out["head"]]:
        flat = arr.reshape(-1)
        n = max(1, int(fraction * flat.size))
        idx = rng.choice(flat.size, size=n, replace=False)
        flat[idx] += rng.normal(size=n).astype(np.float32)
    return out


# --- delta wire format ------------------------------------------------------


def test_delta_roundtrip_is_bitwise_exact():
    rng = np.random.default_rng(0)
    base = _params(rng)
    base_blob = serialize_update(NodeUpdate(base, num_examples=1, node_id="n", counter=0))
    new = _sparse_step(base, rng)
    u = NodeUpdate(new, num_examples=9, node_id="n", counter=1, timestamp=2.5,
                   metrics={"loss": 0.25})
    blob = serialize_update_delta(u, base, content_hash(base_blob))
    u2 = deserialize_update_delta(blob, base)
    assert np.array_equal(u2.params["layer"]["w"], new["layer"]["w"])
    assert np.array_equal(u2.params["head"], new["head"])
    assert (u2.num_examples, u2.counter, u2.timestamp) == (9, 1, 2.5)
    assert u2.metrics == {"loss": 0.25}
    assert peek_meta(blob)["delta_of"] == content_hash(base_blob)


def test_delta_blob_is_smaller_for_sparse_changes():
    rng = np.random.default_rng(1)
    base = _params(rng)
    new = _sparse_step(base, rng, fraction=0.01)
    u = NodeUpdate(new, num_examples=1, node_id="n", counter=1)
    full = serialize_update(u)
    delta = serialize_update_delta(u, base, "h")
    assert len(delta) < 0.5 * len(full)


def test_delta_dense_fallback_and_density():
    rng = np.random.default_rng(2)
    base = _params(rng)
    totally_new = _params(np.random.default_rng(3))
    assert delta_density(totally_new, base) > 0.9
    u = NodeUpdate(totally_new, num_examples=1, node_id="n", counter=1)
    blob = serialize_update_delta(u, base, "h")  # every leaf goes dense
    u2 = deserialize_update_delta(blob, base)
    assert np.array_equal(u2.params["layer"]["w"], totally_new["layer"]["w"])


def test_delta_structural_mismatch_raises():
    rng = np.random.default_rng(4)
    base = _params(rng)
    other = {"different": np.ones((3,), np.float32)}
    u = NodeUpdate(other, num_examples=1, node_id="n", counter=1)
    with pytest.raises(ValueError):
        serialize_update_delta(u, base, "h")


def test_delta_quantized_is_close_not_exact():
    rng = np.random.default_rng(5)
    base = _params(rng)
    new = _sparse_step(base, rng, fraction=0.05)
    u = NodeUpdate(new, num_examples=1, node_id="n", counter=1)
    u2 = deserialize_update_delta(serialize_update_delta(u, base, "h", quantize=True), base)
    w, w2 = new["layer"]["w"], u2.params["layer"]["w"]
    assert not np.array_equal(w, w2) or np.array_equal(w, base["layer"]["w"])
    changed = w != base["layer"]["w"]
    assert np.max(np.abs((w - w2)[changed])) <= np.abs(w[changed]).max() / 127.0 + 1e-6


def test_delta_bfloat16_roundtrip():
    base = {"w": jnp.asarray(np.linspace(-1, 1, 32), jnp.bfloat16)}
    new = {"w": np.asarray(base["w"]).copy()}
    new["w"][3] = np.float32(0.625)  # exactly representable in bfloat16
    u = NodeUpdate(new, num_examples=1, node_id="b", counter=1)
    u2 = deserialize_update_delta(serialize_update_delta(u, base, "h"), base)
    assert u2.params["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(u2.params["w"], np.float32),
                          np.asarray(new["w"], np.float32))


# --- CachingFolder ----------------------------------------------------------


@pytest.mark.parametrize("inner_factory", ["memory", "disk"])
def test_caching_folder_hits_and_invalidation(inner_factory, tmp_path):
    inner = InMemoryFolder() if inner_factory == "memory" else DiskFolder(str(tmp_path))
    folder = CachingFolder(inner)
    folder.put("k", b"abc")
    assert folder.get("k") == b"abc"          # first read populates the cache
    assert folder.misses == 1 and folder.bytes_fetched == 3
    assert folder.get("k") == b"abc"          # second read is a hit
    assert folder.hits == 1 and folder.bytes_saved == 3
    inner.put("k", b"defg")                    # out-of-band overwrite
    assert folder.get("k") == b"defg"          # version changed → refetch
    assert folder.bytes_fetched == 7
    assert folder.get("k") == b"defg"          # now cached again
    assert folder.hits == 2
    folder.put("k", b"hi")                     # own put invalidates, not caches
    assert folder.get("k") == b"hi"
    assert folder.bytes_fetched == 9
    folder.delete("k")
    assert folder.get("k") is None


def test_caching_folder_second_reader_sees_writes(tmp_path):
    writer = DiskFolder(str(tmp_path))
    reader = CachingFolder(DiskFolder(str(tmp_path)))
    writer.put("x", b"one")
    assert reader.get("x") == b"one"
    writer.put("x", b"two")
    assert reader.get("x") == b"two"  # never a stale hit
    stats = reader.cache_stats()
    assert stats["misses"] == 2 and stats["bytes_fetched"] == 6


def test_make_folder_cache_prefix(tmp_path):
    f = make_folder(f"cache+{tmp_path}/store")
    assert isinstance(f, CachingFolder) and isinstance(f.inner, DiskFolder)
    assert isinstance(make_folder("cache+memory://"), CachingFolder)


# --- WeightStore decoded-update cache ----------------------------------------


@pytest.mark.parametrize("inner_factory", ["memory", "disk"])
def test_weightstore_pull_skips_decode_for_unchanged_peers(inner_factory, tmp_path):
    """The decode-side twin of CachingFolder: a peer whose deposit carries an
    unchanged version token is served from the decoded-update cache — no npz
    decode, exact counts asserted."""
    folder = InMemoryFolder() if inner_factory == "memory" else DiskFolder(str(tmp_path))
    writer = WeightStore(folder)
    reader = WeightStore(folder)
    rng = np.random.default_rng(11)
    p1, p2 = _params(rng), _params(rng)
    writer.push(NodeUpdate(p1, num_examples=1, node_id="n1", counter=0))
    writer.push(NodeUpdate(p2, num_examples=1, node_id="n2", counter=0))

    assert len(reader.pull()) == 2
    assert (reader.decode_misses, reader.decode_hits) == (2, 0)
    assert len(reader.pull()) == 2          # nothing changed: all hits
    assert (reader.decode_misses, reader.decode_hits) == (2, 2)

    writer.push(NodeUpdate(_sparse_step(p1, rng), num_examples=1, node_id="n1", counter=1))
    pulled = {u.node_id: u for u in reader.pull()}
    assert pulled["n1"].counter == 1        # fresh blob was decoded, not stale-served
    assert (reader.decode_misses, reader.decode_hits) == (3, 3)  # n1 miss, n2 hit


def test_weightstore_decode_cache_behind_caching_folder(tmp_path):
    """Stacked fast paths: CachingFolder skips the download, the decode cache
    skips the npz decode — the second pull costs neither."""
    disk = DiskFolder(str(tmp_path))
    cached = CachingFolder(disk)
    writer = WeightStore(disk)
    reader = WeightStore(cached)
    rng = np.random.default_rng(12)
    writer.push(NodeUpdate(_params(rng), num_examples=1, node_id="n", counter=0))
    assert len(reader.pull()) == 1
    fetched = cached.bytes_fetched
    assert len(reader.pull()) == 1
    assert reader.decode_hits == 1
    assert cached.bytes_fetched == fetched  # decode hit never even touched get()


def test_weightstore_decode_cache_is_bounded():
    folder = InMemoryFolder()
    store = WeightStore(folder, decode_cache_entries=2)
    rng = np.random.default_rng(13)
    for i in range(5):
        store.push(NodeUpdate(_params(rng), num_examples=1, node_id=f"n{i}", counter=0))
    store.pull()
    assert len(store._decoded_latest) == 2  # LRU-bounded, not fleet-sized
    store.clear()
    assert len(store._decoded_latest) == 0


def test_weightstore_decode_cache_disabled():
    folder = InMemoryFolder()
    store = WeightStore(folder, decode_cache_entries=0)
    store.push(NodeUpdate({"w": np.ones((3,), np.float32)}, num_examples=1,
                          node_id="n", counter=0))
    store.pull()
    store.pull()
    assert store.decode_hits == 0


# --- WeightStore delta transport --------------------------------------------


def test_weightstore_delta_rebases_and_gcs_old_bases(tmp_path):
    folder = DiskFolder(str(tmp_path))
    store = WeightStore(folder, transport="delta", rebase_every=3)
    rng = np.random.default_rng(6)
    params = _params(rng)
    for ctr in range(8):
        params = _sparse_step(params, rng)
        store.push(NodeUpdate(params, num_examples=1, node_id="n", counter=ctr))
    base_keys = [k for k in folder.keys() if k.startswith("base/n/")]
    assert len(base_keys) == 1  # old bases were garbage collected
    pulled = WeightStore(folder).pull_node("n")  # a fresh reader, any transport
    assert pulled.counter == 7
    assert np.array_equal(pulled.params["layer"]["w"], params["layer"]["w"])


def test_weightstore_transport_validation():
    with pytest.raises(ValueError):
        WeightStore(InMemoryFolder(), transport="gzip")
    with pytest.raises(ValueError):
        AsyncFederatedNode(store=WeightStore(InMemoryFolder()), transport="delta")


def test_delta_base_mismatch_reports_leaf():
    rng = np.random.default_rng(7)
    base = _params(rng)
    u = NodeUpdate(_sparse_step(base, rng), num_examples=1, node_id="n", counter=1)
    blob = serialize_update_delta(u, base, "h")
    with pytest.raises((DeltaBaseMismatch, KeyError, ValueError)):
        deserialize_update_delta(blob, {"other": np.zeros((2,), np.float32)})


# --- the acceptance property: bitwise-equal results, fewer bytes ------------


def _run_federation(base_dir, transport, *, adopt, rounds=6, num_nodes=3):
    """Deterministic sequential async federation; every node reads the shared
    DiskFolder through its own CachingFolder (its private cache, as a real
    client on a real mount would). Returns every aggregation result each node
    ever produced, plus total bytes read from the folder.

    ``adopt=False`` is the partial-fine-tuning regime (LoRA-style: pushed
    params evolve by sparse local steps; the global aggregate is tracked but
    not folded back) — the regime where delta encoding pays off. With
    ``adopt=True`` the weighted mean perturbs every entry, deltas go dense,
    and the store falls back to rebasing — correct, just not smaller.
    """
    folders = [CachingFolder(DiskFolder(base_dir)) for _ in range(num_nodes)]
    nodes = [
        AsyncFederatedNode(strategy=FedAvg(), shared_folder=folders[i],
                           node_id=f"n{i}", transport=transport)
        for i in range(num_nodes)
    ]
    rngs = [np.random.default_rng(100 + i) for i in range(num_nodes)]
    params = [_params(np.random.default_rng(42)) for _ in range(num_nodes)]  # common init
    aggregates = []
    for _ in range(rounds):
        for i in range(num_nodes):
            params[i] = _sparse_step(params[i], rngs[i])
            aggregated = nodes[i].update_parameters(params[i], num_examples=10)
            if aggregated is not None:
                aggregates.append(aggregated)
                if adopt:
                    params[i] = aggregated
    bytes_read = sum(f.bytes_fetched for f in folders)
    return aggregates, bytes_read


def test_delta_cached_transport_matches_full_bitwise_with_fewer_bytes(tmp_path):
    full_aggs, full_bytes = _run_federation(str(tmp_path / "full"), "full", adopt=False)
    delta_aggs, delta_bytes = _run_federation(str(tmp_path / "delta"), "delta", adopt=False)
    # identical schedule → bitwise identical aggregation results, every time
    assert len(full_aggs) == len(delta_aggs) > 0
    for pf, pd in zip(full_aggs, delta_aggs):
        assert np.array_equal(pf["layer"]["w"], pd["layer"]["w"])
        assert np.array_equal(pf["head"], pd["head"])
    # ... while reading measurably fewer bytes from the shared folder
    assert delta_bytes < 0.5 * full_bytes, (delta_bytes, full_bytes)


def test_delta_transport_stays_bitwise_exact_when_aggregates_are_adopted(tmp_path):
    """Adopting the aggregate densifies every delta (forced rebases); results
    must still match the full-blob path bitwise."""
    full_aggs, _ = _run_federation(str(tmp_path / "full"), "full", adopt=True, rounds=4)
    delta_aggs, _ = _run_federation(str(tmp_path / "delta"), "delta", adopt=True, rounds=4)
    assert len(full_aggs) == len(delta_aggs) > 0
    for pf, pd in zip(full_aggs, delta_aggs):
        assert np.array_equal(pf["layer"]["w"], pd["layer"]["w"])
        assert np.array_equal(pf["head"], pd["head"])


def test_weightstore_delta_hostile_node_ids_base_gc(tmp_path):
    """Base GC must not cross node borders when ids contain '/'."""
    folder = DiskFolder(str(tmp_path))
    rng = np.random.default_rng(8)
    params = {nid: _params(np.random.default_rng(9)) for nid in ("team", "team/alpha")}
    store = WeightStore(folder, transport="delta", rebase_every=2)
    for ctr in range(5):  # rebase_every=2 → multiple rebases per node
        for nid in params:
            params[nid] = _sparse_step(params[nid], rng)
            store.push(NodeUpdate(params[nid], num_examples=1, node_id=nid, counter=ctr))
    for nid in params:
        bases = [k for k in folder.keys() if k.rpartition("/")[0] == f"base/{nid}"]
        assert len(bases) == 1, (nid, bases)
        pulled = WeightStore(folder).pull_node(nid)
        assert pulled.counter == 4
        assert np.array_equal(pulled.params["layer"]["w"], params[nid]["layer"]["w"])
    assert sorted(store.node_ids()) == ["team", "team/alpha"]


def test_async_skip_check_survives_delta_rebase(tmp_path):
    """A node's own rebase writes base/<node>/<hash>; that must not defeat its
    own state-hash skip check (the whole point of Algorithm 1's fast path)."""
    folder = DiskFolder(str(tmp_path))
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id="solo", transport="delta")
    node.store.rebase_every = 1  # force a rebase (base churn) on every push
    rng = np.random.default_rng(10)
    p = _params(rng)
    assert node.update_parameters(p, num_examples=1) is None
    pulls_before = node.num_pulls
    for _ in range(3):
        p = _sparse_step(p, rng)
        assert node.update_parameters(p, num_examples=1) is None
    assert node.num_pulls == pulls_before  # all skipped via the hash check
    assert node.num_skipped_pulls >= 3


def test_diskfolder_state_hash_changes_on_same_size_rewrite(tmp_path):
    """Same content, same size, potentially same mtime tick — the hash must
    still move (fresh-inode hardening), or peers' updates get skipped."""
    folder = DiskFolder(str(tmp_path))
    folder.put("latest/a", b"same-bytes")
    h1 = folder.state_hash()
    folder.put("latest/a", b"same-bytes")
    assert folder.state_hash() != h1
