"""The store-native observability plane (``repro.core.telemetry``).

Covers the span/counter flight recorder (ring bound, per-phase aggregates,
near-zero disabled path), the ``obs/`` blob family's hygiene (state-hash
exclusion on both store kinds, GC survival, URI round-trips), the node/store
instrumentation seams, thread-safety of ``PipelineStats`` (the regression the
lock fixes), the bounded ``FederatedCallback.history``, the Chrome
trace-event export schema, and the fleet-level rollups an 8-node soak
assembles from blobs alone.
"""
import json
import logging
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (
    AsyncFederatedNode,
    CachingFolder,
    FederatedCallback,
    FleetSpec,
    InMemoryFolder,
    PipelineStats,
    ShardedFolders,
    ShardedWeightStore,
    SpanRecorder,
    Telemetry,
    WeightStore,
    chrome_trace,
    collect_obs,
    deserialize_obs_blob,
    run_fleet_local,
    serialize_obs_blob,
    telemetry_rollups,
)
from repro.core.telemetry import _NULL_SPAN, env_enabled


def _params(n=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal(n).astype(np.float32)}


# --------------------------------------------------------------------------
# SpanRecorder / Telemetry core
# --------------------------------------------------------------------------


class TestSpanRecorder:
    def test_records_events_and_aggregates(self):
        rec = SpanRecorder(capacity=64)
        with rec.span("pull"):
            pass
        with rec.span("pull"):
            pass
        with rec.span("push"):
            pass
        assert len(rec) == 3
        stats = rec.phase_stats()
        assert stats["pull"]["count"] == 2
        assert stats["push"]["count"] == 1
        assert stats["pull"]["min_s"] <= stats["pull"]["max_s"]
        assert stats["pull"]["total_s"] >= 2 * stats["pull"]["min_s"]

    def test_ring_is_bounded_but_aggregates_are_not(self):
        rec = SpanRecorder(capacity=8)
        for _ in range(30):
            with rec.span("x"):
                pass
        assert len(rec) == 8  # ring holds only the most recent events
        assert rec.dropped == 22
        assert rec.total_recorded == 30
        assert rec.phase_stats()["x"]["count"] == 30  # aggregates fold all

    def test_drain_empties_ring_but_keeps_aggregates(self):
        rec = SpanRecorder(capacity=8)
        with rec.span("x"):
            pass
        events = rec.drain()
        assert [e[0] for e in events] == ["x"]
        assert len(rec) == 0
        assert rec.drain() == []
        assert rec.phase_stats()["x"]["count"] == 1

    def test_injected_clock(self):
        t = [0.0]
        rec = SpanRecorder(capacity=8, clock=lambda: t[0])
        span = rec.span("x")
        span.__enter__()
        t[0] = 2.5
        span.__exit__(None, None, None)
        (name, t0, dur), = rec.drain()
        assert (name, t0, dur) == ("x", 0.0, 2.5)


class TestTelemetry:
    def test_disabled_span_is_shared_noop(self):
        tel = Telemetry("n", enabled=False)
        assert tel.span("pull") is _NULL_SPAN
        assert tel.span("push") is _NULL_SPAN  # same object: zero allocation
        with tel.span("pull"):
            pass
        assert len(tel.recorder) == 0
        tel.observe_staleness(3)
        tel.count("x")
        assert tel.staleness_stats()["count"] == 0

    def test_env_gating(self, monkeypatch):
        monkeypatch.delenv("REPRO_OBS", raising=False)
        assert not env_enabled()
        assert Telemetry("n").enabled is False
        monkeypatch.setenv("REPRO_OBS", "1")
        assert env_enabled()
        assert Telemetry("n").enabled is True
        monkeypatch.setenv("REPRO_OBS", "off")
        assert not env_enabled()

    def test_staleness_distribution(self):
        tel = Telemetry("n", enabled=True)
        for v in [0, 1, 1, 2, 10]:
            tel.observe_staleness(v)
        stats = tel.staleness_stats()
        assert stats["count"] == 5
        assert stats["mean"] == pytest.approx(14 / 5)
        assert stats["max"] == 10
        assert stats["p50"] == 1
        assert stats["p90"] == 10

    def test_snapshot_advances_seq_and_carries_deltas(self):
        tel = Telemetry("n0", enabled=True)
        with tel.span("pull"):
            pass
        tel.end_round(aggregated=True)
        p0 = tel.snapshot({"bytes_written": 100, "decode_hits": 3,
                           "decode_misses": 1})
        assert p0["seq"] == 0 and tel.seq == 1
        assert p0["node_id"] == "n0"
        assert p0["rounds"] == 1 and p0["aggregations"] == 1
        assert p0["phases"]["pull"]["count"] == 1
        assert p0["prefetch_hit_rate"] == pytest.approx(0.75)
        assert len(p0["spans"]) == 1
        name, ts_us, dur_us = p0["spans"][0]
        assert name == "pull" and isinstance(ts_us, int) and dur_us >= 0
        # wall-anchored: within a minute of now
        assert abs(ts_us / 1e6 - time.time()) < 60
        tel.end_round(aggregated=False)
        p1 = tel.snapshot({"bytes_written": 300, "decode_hits": 3,
                           "decode_misses": 1})
        assert p1["seq"] == 1
        assert p1["transport_delta"]["bytes_written"] == 200
        assert p1["window"]["rounds"] == 1
        assert p1["spans"] == []  # drained by the previous snapshot

    def test_snapshot_is_json_serializable(self):
        tel = Telemetry("n0", enabled=True)
        with tel.span("push"):
            pass
        tel.observe_staleness(2)
        tel.note_train(10, 0.5)
        tel.end_round(aggregated=True)
        payload = tel.snapshot({"bytes_written": 10})
        json.dumps(payload)  # must not raise


# --------------------------------------------------------------------------
# obs blob family + hygiene
# --------------------------------------------------------------------------


class TestObsBlobs:
    def test_round_trip(self):
        blob = serialize_obs_blob("node-a", 7, {"rounds": 3, "x": 1.5})
        node, seq, payload = deserialize_obs_blob(blob)
        assert (node, seq) == ("node-a", 7)
        assert payload == {"rounds": 3, "x": 1.5}

    def test_non_obs_blob_raises(self):
        from repro.core import serialize_update, NodeUpdate
        blob = serialize_update(NodeUpdate(
            params=_params(), num_examples=1, node_id="n", counter=0,
            timestamp=0.0))
        with pytest.raises(ValueError):
            deserialize_obs_blob(blob)

    def test_excluded_from_flat_state_hash(self):
        store = WeightStore(InMemoryFolder())
        store.push(_nu("a", 0))
        h0 = store.state_hash()
        h0x = store.state_hash(exclude_node="b")
        store.push_obs("a", 0, {"rounds": 1})
        assert store.state_hash() == h0
        assert store.state_hash(exclude_node="b") == h0x
        assert store.pull_obs("a")[0][2] == {"rounds": 1}

    def test_excluded_from_sharded_state_hash(self):
        folders = ShardedFolders.from_folders(
            [InMemoryFolder() for _ in range(4)])
        store = ShardedWeightStore(folders)
        store.push(_nu("a", 0))
        store.push(_nu("b", 0))
        h0 = store.state_hash()
        h0x = store.state_hash(exclude_node="b")
        store.push_obs("a", 0, {"rounds": 1})
        store.push_obs("b", 0, {"rounds": 2})
        assert store.state_hash() == h0
        assert store.state_hash(exclude_node="b") == h0x
        assert len(store.pull_obs()) == 2
        assert store.pull_obs("b")[0][0] == "b"

    def test_survives_keep_history_false_gc(self):
        # delta transport GCs superseded bases/chains aggressively (including
        # the first-rebase leftover sweep); obs/ deposits must survive it
        store = WeightStore(InMemoryFolder(), transport="delta",
                            keep_history=False)
        store.push_obs("a", 0, {"rounds": 0})
        for c in range(6):  # rebase_every default triggers full rebases
            store.push(_nu("a", c, seed=c))
        assert store.pull_obs("a")[0][1] == 0
        assert [k for k in store.folder.keys() if k.startswith("obs/")]

    def test_round_trips_through_cache_uri(self):
        store = WeightStore(CachingFolder(InMemoryFolder()))
        store.push_obs("n", 0, {"rounds": 5})
        assert store.pull_obs()[0] == ("n", 0, {"rounds": 5})

    def test_obs_gc_bounds_trail(self):
        store = WeightStore(InMemoryFolder())
        for seq in range(10):
            store.push_obs("n", seq, {"seq": seq}, keep=4)
        keys = sorted(k for k in store.folder.keys() if k.startswith("obs/"))
        assert keys == [f"obs/n/{s:06d}" for s in range(6, 10)]


def _nu(node_id, counter, seed=0):
    from repro.core import NodeUpdate
    return NodeUpdate(params=_params(seed=seed), num_examples=1,
                      node_id=node_id, counter=counter, timestamp=0.0)


# --------------------------------------------------------------------------
# node integration
# --------------------------------------------------------------------------


class TestNodeIntegration:
    def test_nodes_flush_obs_and_observe_staleness(self):
        folder = InMemoryFolder()
        tel = Telemetry(enabled=True, flush_every=1)
        a = AsyncFederatedNode(shared_folder=folder, node_id="a", telemetry=tel)
        b = AsyncFederatedNode(shared_folder=folder, node_id="b",
                               telemetry=True)
        for i in range(3):
            a.update_parameters(_params(seed=i), 1)
            b.update_parameters(_params(seed=i + 10), 1)
        payloads = a.store.pull_obs("a")
        assert len(payloads) == 3
        last = payloads[-1][2]
        assert last["rounds"] == 3
        assert {"push", "pull"} <= set(last["phases"])
        assert last["staleness"]["count"] >= 1
        assert tel.node_id == "a"  # node filled in the blank id
        # b's telemetry=True default cadence hasn't flushed yet
        assert a.store.pull_obs("b") == []

    def test_default_is_off_and_costs_nothing(self, monkeypatch):
        monkeypatch.delenv("REPRO_OBS", raising=False)
        node = AsyncFederatedNode(shared_folder=InMemoryFolder(), node_id="n")
        assert node.telemetry.enabled is False
        node.update_parameters(_params(), 1)
        assert [k for k in node.store.folder.keys()
                if k.startswith("obs/")] == []

    def test_sharded_node_flushes_to_home_group(self):
        folders = ShardedFolders.from_folders(
            [InMemoryFolder() for _ in range(2)])
        node = AsyncFederatedNode(
            shared_folder=folders, node_id="n0",
            telemetry=Telemetry(enabled=True, flush_every=1))
        node.update_parameters(_params(), 1)
        assert len(node.store.pull_obs("n0")) == 1

    def test_obs_flush_failure_never_breaks_federation(self, monkeypatch):
        node = AsyncFederatedNode(
            shared_folder=InMemoryFolder(), node_id="n",
            telemetry=Telemetry(enabled=True, flush_every=1))
        monkeypatch.setattr(node.store, "push_obs",
                            lambda *a, **k: 1 / 0)
        assert node.update_parameters(_params(), 1) is None  # no peers; no raise
        assert node.counter == 1


# --------------------------------------------------------------------------
# PipelineStats thread-safety (the satellite regression)
# --------------------------------------------------------------------------


class TestPipelineStatsThreadSafety:
    def test_concurrent_incr_loses_nothing(self):
        # Bare `+=` on an instance attribute is load/add/store in CPython —
        # with a tiny switch interval, racing threads routinely lose updates.
        # The locked incr() must be exact.
        stats = PipelineStats()
        threads, per_thread = 8, 2000
        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-6)
        try:
            def work():
                for _ in range(per_thread):
                    stats.incr("bytes_written")
                    stats.incr("bytes_read", 3)
            ts = [threading.Thread(target=work) for _ in range(threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        finally:
            sys.setswitchinterval(old)
        assert stats.bytes_written == threads * per_thread
        assert stats.bytes_read == 3 * threads * per_thread

    def test_record_max_and_set_value(self):
        stats = PipelineStats()
        stats.record_max("max_chain_depth", 3)
        stats.record_max("max_chain_depth", 1)
        assert stats.max_chain_depth == 3
        stats.set_value("chain_depth", 2)
        assert stats.chain_depth == 2

    def test_reset_preserves_lock_identity(self):
        stats = PipelineStats()
        lock = stats._lock
        stats.incr("encodes")
        stats.reset()
        assert stats.encodes == 0
        assert stats._lock is lock  # a swapped lock would orphan waiters

    def test_as_dict_snapshot(self):
        stats = PipelineStats()
        stats.incr("decodes", 5)
        d = stats.as_dict()
        assert d["decodes"] == 5 and "residual_norm" in d


# --------------------------------------------------------------------------
# bounded callback history
# --------------------------------------------------------------------------


class TestHistoryCap:
    class _StubStore:
        def stop_prefetch(self):
            pass

    class _StubNode:
        def __init__(self):
            self.store = TestHistoryCap._StubStore()

        def update_parameters(self, params, num_examples, metrics=None):
            return None

    class _StubTrainer:
        def host_params(self):
            return {}

    def test_history_is_bounded(self):
        cb = FederatedCallback(self._StubNode(), num_examples_per_epoch=1,
                               history_limit=5)
        trainer = self._StubTrainer()
        for epoch in range(50):
            cb.on_epoch_end(trainer, epoch, {})
        assert len(cb.history) == 5
        assert [h["epoch"] for h in cb.history] == list(range(45, 50))

    def test_default_cap_exists(self):
        cb = FederatedCallback(self._StubNode(), num_examples_per_epoch=1)
        assert cb.history.maxlen == 10_000


# --------------------------------------------------------------------------
# Chrome trace export
# --------------------------------------------------------------------------


def assert_valid_chrome_trace(doc):
    """Minimal Chrome trace-event format check (the JSON object form)."""
    assert isinstance(doc, dict)
    events = doc["traceEvents"]
    assert isinstance(events, list) and events
    for e in events:
        assert isinstance(e["name"], str)
        assert e["ph"] in ("X", "M")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert isinstance(e["ts"], int) and e["ts"] >= 0
            assert isinstance(e["dur"], int) and e["dur"] >= 0
        else:
            assert e["name"] == "process_name"
            assert isinstance(e["args"]["name"], str)
    json.dumps(doc)


class TestTraceExport:
    def test_chrome_trace_schema(self):
        tel = Telemetry("n0", enabled=True)
        for phase in ("pull", "aggregate", "push"):
            with tel.span(phase):
                pass
        payload = tel.snapshot()
        doc = chrome_trace({"n0": [payload]})
        assert_valid_chrome_trace(doc)
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert {e["name"] for e in spans} == {"pull", "aggregate", "push"}

    def test_nodes_become_processes(self):
        t0 = Telemetry("a", enabled=True)
        t1 = Telemetry("b", enabled=True)
        for tel in (t0, t1):
            with tel.span("pull"):
                pass
        doc = chrome_trace({"a": [t0.snapshot()], "b": [t1.snapshot()]})
        metas = {e["args"]["name"]: e["pid"]
                 for e in doc["traceEvents"] if e["ph"] == "M"}
        assert set(metas) == {"a", "b"}
        assert len(set(metas.values())) == 2


# --------------------------------------------------------------------------
# rollups + the 8-node soak acceptance
# --------------------------------------------------------------------------


class TestRollups:
    def test_rollups_from_synthetic_payloads(self):
        def payload(node, rounds, t, stale_mean):
            return {
                "node_id": node, "rounds": rounds, "aggregations": rounds,
                "time_unix": t,
                "phases": {"pull": {"count": rounds, "total_s": 0.01 * rounds,
                                    "mean_s": 0.01, "min_s": 0.01,
                                    "max_s": 0.01}},
                "staleness": {"count": rounds, "mean": stale_mean,
                              "p50": stale_mean, "p90": stale_mean,
                              "max": stale_mean},
                "transport": {"bytes_written": 100 * rounds},
                "window": {"rounds_per_sec": 1.0},
                "train": {"steps_per_sec": 5.0},
            }

        obs = {
            "a": [payload("a", 2, 100.0, 1.0), payload("a", 6, 102.0, 1.0)],
            "b": [payload("b", 4, 101.0, 3.0)],
        }
        roll = telemetry_rollups(obs)
        assert roll["fleet"]["nodes_reporting"] == 2
        # a: 4 rounds over 2s from first->last payload
        assert roll["nodes"]["a"]["rounds_per_sec"] == pytest.approx(2.0)
        assert roll["nodes"]["a"]["rounds"] == 6
        assert roll["fleet"]["staleness_mean"] == pytest.approx(2.0)
        assert roll["fleet"]["phase_ms"]["pull"] == pytest.approx(10.0)
        assert roll["fleet"]["bytes_written"] == 1000

    def test_empty_rollups(self):
        roll = telemetry_rollups({})
        assert roll["fleet"]["nodes_reporting"] == 0
        assert roll["nodes"] == {}


@pytest.mark.slow
def test_eight_node_soak_report_and_trace(tmp_path, capsys):
    """Acceptance: an 8-node soak's SoakReport carries per-node staleness +
    phase rollups assembled from obs/ blobs alone, and ``repro.obs trace``
    exports schema-valid Chrome trace JSON from the same store."""
    store = str(tmp_path / "soak")
    spec = FleetSpec(store_uri=store, name="obs-soak", num_nodes=8, rounds=3,
                     runner="thread", round_sleep=0.01, settle=0.2,
                     result_timeout=60)
    report = run_fleet_local(spec, num_workers=2)
    assert report.passed
    tel = report.telemetry
    assert tel["fleet"]["nodes_reporting"] == 8
    for node_id in spec.node_ids():
        per = tel["nodes"][node_id]
        assert per["rounds"] >= spec.rounds
        assert "staleness_mean" in per and "staleness_p90" in per
        assert {"pull", "push"} <= set(per["phase_ms"])
    assert "telemetry: 8/8 nodes" in report.summary()
    # the dashboard renders from blobs alone
    import repro.obs as obs_cli
    assert obs_cli.main(["watch", "--store", store, "--once"]) == 0
    out = capsys.readouterr().out
    assert "8 nodes reporting" in out
    # and the trace exporter emits valid Chrome trace JSON
    trace_path = str(tmp_path / "trace.json")
    assert obs_cli.main(["trace", "--store", store, "--out", trace_path]) == 0
    doc = json.load(open(trace_path))
    assert_valid_chrome_trace(doc)
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert len(pids) == 8


# --------------------------------------------------------------------------
# logging knob
# --------------------------------------------------------------------------


class TestLogs:
    def test_silent_by_default(self):
        from repro.logs import get_logger
        logger = get_logger("test")
        assert logger.name == "repro.test"
        root = logging.getLogger("repro")
        assert any(isinstance(h, logging.NullHandler)
                   for h in root.handlers) or not root.handlers

    def test_configure_and_teardown(self):
        import io
        from repro.logs import configure, get_logger
        stream = io.StringIO()
        configure("debug", stream=stream)
        try:
            get_logger("x").debug("hello from the test")
            assert "hello from the test" in stream.getvalue()
        finally:
            configure(None)
        stream2 = io.StringIO()
        configure("warning", stream=stream2)
        try:
            get_logger("x").debug("should not appear")
            assert stream2.getvalue() == ""
        finally:
            configure(None)

    def test_scoped_configure(self):
        import io
        from repro.logs import configure, get_logger
        stream = io.StringIO()
        configure("debug:fleet", stream=stream)
        try:
            get_logger("fleet").debug("fleet event")
            get_logger("store").debug("store event")
            text = stream.getvalue()
            assert "fleet event" in text
            assert "store event" not in text
        finally:
            configure(None)
