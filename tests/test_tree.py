import jax
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.tree import (
    tree_allclose,
    tree_flatten_to_vector,
    tree_mean,
    tree_num_params,
    tree_paths,
    tree_weighted_mean,
    tree_weighted_sum,
)


def make_tree(vals):
    return {"a": {"w": np.full((2, 3), vals[0], np.float32)}, "b": np.full((4,), vals[1], np.float32)}


def test_weighted_mean_normalizes():
    t = tree_weighted_mean([make_tree([1, 2]), make_tree([3, 4])], [1, 3])
    assert np.allclose(t["a"]["w"], 2.5)
    assert np.allclose(t["b"], 3.5)


def test_weighted_sum_validates():
    with pytest.raises(ValueError):
        tree_weighted_sum([make_tree([1, 1])], [1.0, 2.0])
    with pytest.raises(ValueError):
        tree_weighted_mean([make_tree([1, 1])], [0.0])


@settings(max_examples=30, deadline=None)
@given(
    weights=st.lists(st.floats(0.1, 100.0), min_size=1, max_size=6),
    base=st.floats(-10, 10),
)
def test_weighted_mean_is_convex_combination(weights, base):
    """Mean of constant trees lies within [min, max] of inputs (hypothesis)."""
    vals = [base + i for i in range(len(weights))]
    trees = [make_tree([v, v]) for v in vals]
    out = tree_weighted_mean(trees, weights)
    assert out["a"]["w"].min() >= min(vals) - 1e-4
    assert out["a"]["w"].max() <= max(vals) + 1e-4


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.5, 5.0), min_size=2, max_size=5))
def test_weighted_mean_identity(weights):
    """Aggregating identical trees returns the same tree, any weights."""
    t = make_tree([1.25, -3.5])
    out = tree_weighted_mean([t] * len(weights), weights)
    assert tree_allclose(out, t, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.permutations(list(range(4))))
def test_weighted_mean_permutation_invariant(perm):
    trees = [make_tree([i, -i]) for i in range(4)]
    weights = [1.0, 2.0, 3.0, 4.0]
    ref = tree_weighted_mean(trees, weights)
    out = tree_weighted_mean([trees[i] for i in perm], [weights[i] for i in perm])
    assert tree_allclose(ref, out, rtol=1e-5, atol=1e-6)


def test_flatten_to_vector_roundtrip():
    t = {"x": np.arange(6, dtype=np.float32).reshape(2, 3), "y": {"z": np.ones((4,), np.int32)}}
    flat, unflatten = tree_flatten_to_vector(t)
    assert flat.shape == (10,)
    t2 = unflatten(flat)
    assert np.array_equal(t2["x"], t["x"])
    assert np.array_equal(t2["y"]["z"], t["y"]["z"])
    assert t2["y"]["z"].dtype == np.int32


def test_paths_and_count():
    t = make_tree([0, 0])
    assert tree_paths(t) == ["a/w", "b"]
    assert tree_num_params(t) == 10
