"""Sharded gossip weight store: assignment stability, URI routing, O(group)
scan structure, and the diameter-bounded convergence property.

The acceptance property: an update deposited in any group reaches EVERY
populated group's folder within ``num_groups`` gossip rounds (the ring
diameter), under adversarial per-round push orderings.
"""
import warnings

import numpy as np
import pytest

from _hyp import given, settings, strategies as st

from repro.core import (
    AsyncFederatedNode,
    DiskFolder,
    GroupSummary,
    InMemoryFolder,
    NodeUpdate,
    ShardedFolders,
    ShardedWeightStore,
    SyncFederatedNode,
    WeightStore,
    balanced_groups,
    default_group_of,
    deserialize_group_summary,
    make_folder,
    peek_meta,
    run_threaded,
    serialize_group_summary,
)
from repro.core.gossip import GROUP_PEER_PREFIX
from repro.core.store import CachingFolder
from repro.core.strategies import FedAvg


def params(v, n=4):
    return {"w": np.full((n,), float(v), np.float32)}


def fresh_sharded(num_groups, levels=1, **kwargs):
    return ShardedWeightStore(
        ShardedFolders(num_groups, levels=levels,
                       factory=lambda g: InMemoryFolder()), **kwargs
    )


# --- summary wire format -----------------------------------------------------


def test_group_summary_roundtrip_and_meta_dispatch():
    s = GroupSummary(
        params=params(1.5),
        num_examples=42,
        origin=3,
        version=17,
        version_vector={"a": 4, "b": 11},
        timestamp=2.25,
    )
    blob = serialize_group_summary(s)
    assert peek_meta(blob)["summary_of"] == 3  # cheap dispatch, like delta_of
    s2 = deserialize_group_summary(blob)
    assert np.array_equal(s2.params["w"], s.params["w"])
    assert (s2.num_examples, s2.origin, s2.version, s2.timestamp) == (42, 3, 17, 2.25)
    assert s2.version_vector == {"a": 4, "b": 11}


def test_deserialize_group_summary_rejects_non_summary():
    from repro.core import serialize_update

    blob = serialize_update(NodeUpdate(params(0.0), num_examples=1, node_id="n"))
    with pytest.raises(ValueError):
        deserialize_group_summary(blob)


def test_super_summary_roundtrip_and_meta_dispatch():
    from repro.core import SuperSummary, deserialize_super_summary, serialize_super_summary

    s = SuperSummary(
        params=params(2.5),
        num_examples=120,
        origin=2,
        level=1,
        version=31,
        child_versions={"6": 14, "7": 17},
        version_vector={"group:6": 4, "group:7": 9},
        timestamp=3.5,
    )
    blob = serialize_super_summary(s)
    meta = peek_meta(blob)  # cheap dispatch, like summary_of / delta_of
    assert meta["super_summary_of"] == 2 and meta["level"] == 1
    s2 = deserialize_super_summary(blob)
    assert np.array_equal(s2.params["w"], s.params["w"])
    assert (s2.num_examples, s2.origin, s2.level, s2.version, s2.timestamp) == (
        120, 2, 1, 31, 3.5)
    assert s2.child_versions == {"6": 14, "7": 17}
    assert s2.version_vector == {"group:6": 4, "group:7": 9}
    # a plain group summary is NOT a super-summary
    g = GroupSummary(params=params(1.0), num_examples=1, origin=0, version=1,
                     version_vector={"a": 0})
    with pytest.raises(ValueError):
        deserialize_super_summary(serialize_group_summary(g))


# --- group assignment properties ---------------------------------------------


@settings(max_examples=25)
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=40), st.integers(1, 12))
def test_default_assignment_stable_and_in_range(raw_ids, num_groups):
    node_ids = [f"node{v}" for v in raw_ids]
    for nid in node_ids:
        g = default_group_of(nid, num_groups)
        assert 0 <= g < num_groups
        # stability: recomputing from an equal-but-distinct string agrees
        assert default_group_of(str(nid), num_groups) == g


@settings(max_examples=25)
@given(
    st.lists(st.integers(0, 10**6), min_size=1, max_size=40),
    st.integers(1, 8),
    st.integers(0, 2**31 - 1),
)
def test_balanced_groups_stable_and_covering(raw_ids, num_groups, seed):
    node_ids = [f"node{v}" for v in raw_ids]
    mapping = balanced_groups(node_ids, num_groups)
    # same SET, any order (and with duplicates collapsed) -> same mapping
    shuffled = list(dict.fromkeys(node_ids))
    np.random.default_rng(seed).shuffle(shuffled)
    assert balanced_groups(shuffled, num_groups) == mapping
    assert balanced_groups(reversed(node_ids), num_groups) == mapping
    sizes = np.bincount(list(mapping.values()), minlength=num_groups)
    assert sizes.max() - sizes.min() <= 1
    if len(mapping) >= num_groups:
        assert sizes.min() >= 1  # no empty group once n >= num_groups


# --- the convergence bound ---------------------------------------------------


def _run_round(store, counters, order):
    """One gossip round: every node pushes exactly once, in ``order``."""
    for nid in order:
        counters[nid] += 1
        store.push(
            NodeUpdate(params(counters[nid]), num_examples=1, node_id=nid,
                       counter=counters[nid])
        )


def _groups_holding(store, origin, node_id, min_counter):
    """Set of groups whose folder holds a summary of ``origin`` that has
    folded in ``node_id``'s update at >= ``min_counter``."""
    out = set()
    for g in range(store.num_groups):
        s = store.load_summary(g, origin)
        if s is not None and s.version_vector.get(node_id, -1) >= min_counter:
            out.add(g)
    return out


@settings(max_examples=8)
@given(st.integers(2, 5), st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_update_reaches_every_group_within_diameter(num_groups, per_group, seed):
    """The acceptance bound: after a distinguished node's push, every group
    holds that update's effect within num_groups gossip rounds, for any
    per-round push ordering."""
    node_ids = [f"n{i}" for i in range(num_groups * per_group)]
    mapping = {nid: i % num_groups for i, nid in enumerate(node_ids)}
    store = fresh_sharded(num_groups, group_of=mapping)
    rng = np.random.default_rng(seed)
    counters = {nid: -1 for nid in node_ids}

    order = list(node_ids)
    rng.shuffle(order)
    _run_round(store, counters, order)  # seed round: everyone deposits once

    # the distinguished update: n0 (group 0) pushes counter c
    counters["n0"] += 1
    c = counters["n0"]
    store.push(NodeUpdate(params(99.0), num_examples=1, node_id="n0", counter=c))

    rounds_needed = None
    for r in range(1, num_groups + 1):
        order = list(node_ids)
        rng.shuffle(order)
        _run_round(store, counters, order)
        if _groups_holding(store, origin=0, node_id="n0", min_counter=c) == set(
            range(num_groups)
        ):
            rounds_needed = r
            break
    assert rounds_needed is not None and rounds_needed <= num_groups


def test_gossip_rides_over_empty_groups():
    """Hash-assigned fleets can leave groups empty; forwarding walks past
    holes (seeding them en route) so the ring never partitions."""
    num_groups = 6
    mapping = {"a": 0, "b": 3}  # groups 1,2,4,5 are empty
    store = fresh_sharded(num_groups, group_of=mapping)
    counters = {"a": -1, "b": -1}
    for _ in range(num_groups + 1):
        _run_round(store, counters, ["a", "b"])
    # both populated groups hear about each other
    assert store.load_summary(3, 0) is not None  # a's summary reached b's group
    assert store.load_summary(0, 3) is not None  # and vice versa
    # a's pull folds in b's group summary as a pseudo-peer
    peers = store.pull(exclude="a")
    assert f"{GROUP_PEER_PREFIX}3" in {u.node_id for u in peers}


def test_summary_versions_gc_to_one_per_origin():
    store = fresh_sharded(2, group_of={"a": 0, "b": 1})
    counters = {"a": -1, "b": -1}
    for _ in range(5):
        _run_round(store, counters, ["a", "b"])
    for g in range(2):
        keys = [k for k in store.folders.group_folder(g).keys() if k.startswith("summary/")]
        origins = [k.split("/")[1] for k in keys]
        assert len(origins) == len(set(origins)), keys  # one version per origin


# --- O(group) scan structure -------------------------------------------------


def test_state_hash_and_pull_touch_only_home_group():
    """A node's per-step scan is its home folder only: activity confined to a
    foreign (non-adjacent-summary) node's latest/ never perturbs it."""

    class CountingFolder(InMemoryFolder):
        def __init__(self):
            super().__init__()
            self.ops = 0

        def keys(self):
            self.ops += 1
            return super().keys()

        def get(self, key):
            self.ops += 1
            return super().get(key)

    folders = [CountingFolder() for _ in range(4)]
    mapping = {f"n{i}": i % 4 for i in range(8)}
    store = ShardedWeightStore(ShardedFolders.from_folders(folders), group_of=mapping)
    counters = {nid: -1 for nid in mapping}
    _run_round(store, counters, list(mapping))

    for f in folders:
        f.ops = 0
    store.state_hash(exclude_node="n0")  # n0 lives in group 0
    store.pull(exclude="n0")
    assert folders[0].ops > 0
    assert folders[1].ops == folders[2].ops == folders[3].ops == 0


def test_own_push_does_not_defeat_skip_check():
    """Algorithm 1's fast path survives sharding: a push refreshes the home
    group's summary, but that summary is excluded from the pusher's own
    state hash."""
    store = fresh_sharded(3, group_of={"solo": 1})
    node = AsyncFederatedNode(strategy=FedAvg(), store=store, node_id="solo")
    assert node.update_parameters(params(1.0), 10) is None
    pulls = node.num_pulls
    for i in range(3):
        assert node.update_parameters(params(float(i)), 10) is None
    assert node.num_pulls == pulls
    assert node.num_skipped_pulls >= 3


# --- nodes on a ShardedWeightStore (the existing contracts, unchanged) -------


def test_async_same_group_nodes_aggregate():
    shared = ShardedFolders(2, factory=lambda g: InMemoryFolder())
    mapping = {"a": 0, "b": 0}
    a = AsyncFederatedNode(strategy=FedAvg(),
                           store=ShardedWeightStore(shared, group_of=mapping),
                           node_id="a")
    b = AsyncFederatedNode(strategy=FedAvg(),
                           store=ShardedWeightStore(shared, group_of=mapping),
                           node_id="b")
    assert a.update_parameters(params(0.0), 10) is None
    out = b.update_parameters(params(2.0), 10)
    assert out is not None and np.allclose(out["w"], 1.0)


def test_async_cross_group_nodes_converge_via_summaries():
    shared = ShardedFolders(2, factory=lambda g: InMemoryFolder())
    mapping = {"a": 0, "b": 1}
    a = AsyncFederatedNode(strategy=FedAvg(),
                           store=ShardedWeightStore(shared, group_of=mapping),
                           node_id="a")
    b = AsyncFederatedNode(strategy=FedAvg(),
                           store=ShardedWeightStore(shared, group_of=mapping),
                           node_id="b")
    outs = []
    for _ in range(3):
        outs.append(a.update_parameters(params(0.0), 10))
        outs.append(b.update_parameters(params(4.0), 10))
    folded = [o for o in outs if o is not None]
    assert folded, "cross-group summaries never arrived"
    # aggregates sit strictly between the two targets: remote info was mixed in
    for o in folded:
        assert 0.0 < float(o["w"][0]) < 4.0


def test_sync_barrier_is_per_group_under_sharding():
    shared = ShardedFolders(2, factory=lambda g: InMemoryFolder())
    mapping = {"a0": 0, "a1": 0, "b0": 1, "b1": 1}
    values = {"a0": 0.0, "a1": 2.0, "b0": 10.0, "b1": 14.0}
    outs = {}

    def client(nid):
        node = SyncFederatedNode(
            strategy=FedAvg(),
            store=ShardedWeightStore(shared, group_of=mapping, keep_history=True),
            node_id=nid, num_nodes=2, timeout=10,
        )
        outs[nid] = node.update_parameters(params(values[nid]), 10)

    res = run_threaded([lambda n=n: client(n) for n in mapping])
    assert all(r.error is None for r in res), [r.traceback for r in res]
    assert np.allclose(outs["a0"]["w"], 1.0) and np.allclose(outs["a1"]["w"], 1.0)
    assert np.allclose(outs["b0"]["w"], 12.0) and np.allclose(outs["b1"]["w"], 12.0)


def test_summary_pseudo_peer_counter_is_in_node_counter_units():
    """Staleness strategies (FedAsync) compare peer counters against their own
    epoch counter; a summary pseudo-peer must report the freshest member's
    counter, not the version scalar (regression)."""
    store = fresh_sharded(2, group_of={"a": 0, "b": 1})
    store.push(NodeUpdate(params(0.0), num_examples=1, node_id="a", counter=0))
    for ctr in range(4):  # group 0 is populated: every push forwards fresh
        store.push(NodeUpdate(params(1.0), num_examples=3, node_id="b", counter=ctr))
    pseudo = [u for u in store.pull(exclude="a")
              if u.node_id == f"{GROUP_PEER_PREFIX}1"]
    assert pseudo and pseudo[0].counter == 3      # freshest member's counter
    assert pseudo[0].metrics["summary_version"] == 4  # scalar still available


def test_rotation_survives_hash_skip_on_quiet_folder():
    """With more foreign origins than summary_sample and a folder gone quiet,
    the state-hash nudge keeps an async node pulling until every group's
    summary has been folded in — then the skip check re-engages (regression:
    the skip froze the rotation and starved unsampled groups forever)."""
    num_groups = 5
    mapping = {f"n{i}": i for i in range(num_groups)}
    shared = ShardedFolders(num_groups, factory=lambda g: InMemoryFolder())
    seed_store = ShardedWeightStore(shared, group_of=mapping)
    counters = {nid: -1 for nid in mapping}
    for _ in range(num_groups + 1):
        _run_round(seed_store, counters, list(mapping))

    class Recording(FedAvg):
        def __init__(self):
            super().__init__()
            self.seen = set()

        def aggregate(self, own, peers):
            self.seen.update(u.node_id for u in peers)
            return super().aggregate(own, peers)

    strat = Recording()
    store = ShardedWeightStore(shared, group_of=mapping, summary_sample=1)
    node = AsyncFederatedNode(strategy=strat, store=store, node_id="n0",
                              resume=False)
    for i in range(3 * num_groups):  # the rest of the fleet stays silent
        node.update_parameters(params(float(i)), 10)
    assert {f"{GROUP_PEER_PREFIX}{g}" for g in range(1, num_groups)} <= strat.seen
    # coverage complete -> the hash settles and the skip fast path returns
    skipped_before = node.num_skipped_pulls
    for i in range(3):
        node.update_parameters(params(float(i)), 10)
    assert node.num_skipped_pulls >= skipped_before + 3


def test_rotation_covers_all_origins_per_node_on_shared_instance():
    """The rotation window is per pulling node: two nodes alternating pulls
    through ONE shared store instance must each still cover every foreign
    origin (regression: a store-global counter strode past half of them)."""
    num_groups = 5
    mapping = {f"n{i}": i for i in range(num_groups)}
    shared = ShardedFolders(num_groups, factory=lambda g: InMemoryFolder())
    seed = ShardedWeightStore(shared, group_of=mapping)
    counters = {nid: -1 for nid in mapping}
    for _ in range(num_groups + 1):
        _run_round(seed, counters, list(mapping))

    store = ShardedWeightStore(shared, group_of=mapping, summary_sample=1)
    seen = {"n0": set(), "n1": set()}
    for _ in range(10):  # strict alternation through the shared instance
        for nid in seen:
            seen[nid].update(u.node_id for u in store.pull(exclude=nid)
                             if u.node_id.startswith(GROUP_PEER_PREFIX))
    for nid, g in (("n0", 0), ("n1", 1)):
        expect = {f"{GROUP_PEER_PREFIX}{o}" for o in range(num_groups) if o != g}
        assert seen[nid] == expect, (nid, seen[nid])


def test_pull_summary_sample_is_bounded_and_rotates():
    num_groups = 9
    mapping = {f"n{i}": i for i in range(num_groups)}
    store = fresh_sharded(num_groups, group_of=mapping, summary_sample=3)
    counters = {nid: -1 for nid in mapping}
    for _ in range(num_groups + 1):  # enough rounds for full propagation
        _run_round(store, counters, list(mapping))
    seen = set()
    for _ in range(8):
        peers = store.pull(exclude="n0")
        pseudo = [u for u in peers if u.node_id.startswith(GROUP_PEER_PREFIX)]
        assert len(pseudo) <= 3  # bounded per pull
        seen.update(u.node_id for u in pseudo)
    # ...but rotation eventually samples every foreign origin
    assert seen == {f"{GROUP_PEER_PREFIX}{g}" for g in range(1, num_groups)}


# --- shard URI routing -------------------------------------------------------


def test_make_folder_shard_uri(tmp_path):
    sf = make_folder("shard8+memory://")
    assert isinstance(sf, ShardedFolders) and sf.num_groups == 8
    assert isinstance(sf.group_folder(0), InMemoryFolder)
    assert sf.group_folder(0) is sf.group_folder(0)  # cached instance
    assert sf.group_folder(0) is not sf.group_folder(1)

    sfd = make_folder(f"shard4+{tmp_path}/exp")
    assert isinstance(sfd.group_folder(2), DiskFolder)
    assert sfd.group_uri(2) == f"{tmp_path}/exp/group0002"

    sfc = make_folder(f"shard2+cache+{tmp_path}/exp2")
    assert sfc.group_uri(1) == f"cache+{tmp_path}/exp2/group0001"
    assert isinstance(sfc.group_folder(1), CachingFolder)


def test_make_folder_plain_shard_path_is_not_a_shard_uri(tmp_path):
    # a directory literally named 'shardware' must stay a DiskFolder
    f = make_folder(str(tmp_path / "shardware"))
    assert isinstance(f, DiskFolder)


def test_node_accepts_shard_uri_folder():
    node = AsyncFederatedNode(strategy=FedAvg(),
                              shared_folder=make_folder("shard4+memory://"),
                              node_id="x")
    assert isinstance(node.store, ShardedWeightStore)
    assert node.update_parameters(params(1.0), 10) is None
    assert node.store.node_ids() == ["x"]


def test_shard_validation_errors(tmp_path):
    with pytest.raises(ValueError):
        ShardedFolders(0, "memory://")
    with pytest.raises(ValueError):
        ShardedFolders(2)  # neither uri nor factory
    with pytest.raises(ValueError):
        ShardedFolders.from_uri("cache+memory://")
    with pytest.raises(ValueError):
        ShardedWeightStore("shard2+memory://", transport="gzip")
    with pytest.raises(ValueError):
        ShardedWeightStore("shard2+memory://", gossip_fanout=0)
    with pytest.raises(ValueError):
        ShardedWeightStore("shard2+memory://", summary_sample=0)
    store = ShardedWeightStore("shard2+memory://",
                               group_of=lambda nid: 7)  # out of range
    with pytest.raises(ValueError):
        store.push(NodeUpdate(params(0.0), num_examples=1, node_id="n"))


def test_sharded_store_works_with_delta_transport(tmp_path):
    store = ShardedWeightStore(f"shard2+{tmp_path}", group_of={"a": 0, "b": 1},
                               transport="delta")
    for ctr in range(3):
        store.push(NodeUpdate(params(ctr), num_examples=1, node_id="a", counter=ctr))
        store.push(NodeUpdate(params(-ctr), num_examples=1, node_id="b", counter=ctr))
    pulled = store.pull_node("a")
    assert pulled.counter == 2 and np.allclose(pulled.params["w"], 2.0)
    assert sorted(store.node_ids()) == ["a", "b"]


# --- restart/recovery (read-your-own-writes bootstrap) -----------------------


def test_node_resumes_counter_and_params_from_own_blob():
    folder = InMemoryFolder()
    first = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="ph")
    for i in range(3):
        first.update_parameters(params(float(i)), 10)
    assert first.counter == 3

    reborn = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="ph")
    assert reborn.resumed is not None
    assert reborn.counter == 3  # continues after its last deposit (counter 2)
    assert np.allclose(reborn.resumed.params["w"], 2.0)

    fresh = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="ph",
                               resume=False)
    assert fresh.resumed is None and fresh.counter == 0


def test_node_resume_routes_through_sharded_store():
    shared = ShardedFolders(3, factory=lambda g: InMemoryFolder())
    mapping = {"ph": 2}
    first = AsyncFederatedNode(strategy=FedAvg(),
                               store=ShardedWeightStore(shared, group_of=mapping),
                               node_id="ph")
    first.update_parameters(params(5.0), 10)
    reborn = AsyncFederatedNode(strategy=FedAvg(),
                                store=ShardedWeightStore(shared, group_of=mapping),
                                node_id="ph")
    assert reborn.resumed is not None and reborn.counter == 1
    assert np.allclose(reborn.resumed.params["w"], 5.0)


def test_generated_node_id_skips_resume_lookup():
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=InMemoryFolder())
    assert node.resumed is None and node.counter == 0


def test_sync_node_does_not_auto_resume():
    """A resuming sync node would wait on a round its peers never reach while
    they aggregate its stale history blobs — sync resume is explicit opt-in."""
    folder = InMemoryFolder()
    first = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id="s", num_nodes=1, timeout=1)
    first.update_parameters(params(1.0), 10)
    again = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id="s", num_nodes=1, timeout=1)
    assert again.resumed is None and again.counter == 0
    opted_in = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                                 node_id="s", num_nodes=1, timeout=1, resume=True)
    assert opted_in.resumed is not None and opted_in.counter == 1


def test_clear_drops_summary_cache():
    """Version scalars restart after clear(); cached decodes keyed on the old
    keys must not survive into the reborn store (regression: pull after clear
    served pre-clear params)."""
    store = fresh_sharded(2, group_of={"a": 0, "b": 1})
    store.push(NodeUpdate(params(111.0), num_examples=1, node_id="b", counter=0))
    store.push(NodeUpdate(params(0.0), num_examples=1, node_id="a", counter=0))
    store.push(NodeUpdate(params(0.0), num_examples=1, node_id="a", counter=1))
    before = [u for u in store.pull(exclude="a")
              if u.node_id == f"{GROUP_PEER_PREFIX}1"]
    assert before and np.allclose(before[0].params["w"], 111.0)

    store.clear()
    store.push(NodeUpdate(params(222.0), num_examples=1, node_id="b", counter=0))
    store.push(NodeUpdate(params(0.0), num_examples=1, node_id="a", counter=0))
    store.push(NodeUpdate(params(0.0), num_examples=1, node_id="a", counter=1))
    after = [u for u in store.pull(exclude="a")
             if u.node_id == f"{GROUP_PEER_PREFIX}1"]
    assert after and np.allclose(after[0].params["w"], 222.0)


def test_summary_index_breaks_version_ties_deterministically():
    """Racing refreshes can land the same version scalar with different
    content; the content-hash suffix makes the keys distinct and every folder
    pick the same winner."""
    from repro.core.gossip import ShardedWeightStore as S

    keys = ["summary/0001/000000000010-aaaa1111",
            "summary/0001/000000000010-bbbb2222",
            "summary/0001/000000000009-cccc3333"]
    index = S._summary_index(keys)
    version, winner, stale = index[(0, "0001")]
    assert winner == "summary/0001/000000000010-bbbb2222"
    assert set(stale) == set(keys) - {winner}
    # and a higher version always beats any hash
    index2 = S._summary_index(keys + ["summary/0001/000000000011-0000aaaa"])
    assert index2[(0, "0001")][1] == "summary/0001/000000000011-0000aaaa"
    # tier keys index separately from same-origin level-0 keys
    index3 = S._summary_index(keys + ["summary1/0001/000000000007-dddd4444"])
    assert index3[(1, "0001")][1] == "summary1/0001/000000000007-dddd4444"
    assert index3[(0, "0001")][1] == winner


def test_forward_seeds_empty_groups_once_not_per_push():
    """Per-push cost must not scale with the number of empty groups: holes on
    the ring are seeded once per origin (and skipped between rechecks), not
    rewritten on every push."""

    class CountingFolder(InMemoryFolder):
        def __init__(self):
            super().__init__()
            self.puts = 0
            self.lists = 0

        def put(self, key, blob):
            self.puts += 1
            super().put(key, blob)

        def keys(self):
            self.lists += 1
            return super().keys()

    folders = [CountingFolder() for _ in range(6)]
    store = ShardedWeightStore(ShardedFolders.from_folders(folders),
                               group_of={"solo": 0})
    for i in range(40):
        store.push(NodeUpdate(params(float(i)), num_examples=1, node_id="solo",
                              counter=i))
    for empty in folders[1:]:
        assert empty.puts <= 2, empty.puts          # seeded, not kept fresh
        assert empty.lists <= 10, empty.lists       # memoized between rechecks


def test_newly_populated_group_joins_the_ring():
    """A group that gains its first member after being memoized empty starts
    receiving forwards again within the recheck window."""
    store = fresh_sharded(3, group_of={"a": 0, "late": 2})
    counters = {"a": -1}
    for _ in range(3):
        _run_round(store, counters, ["a"])  # group 2 memoized empty
    counters["late"] = -1
    for _ in range(20):  # within the recheck window + a propagation round
        _run_round(store, counters, ["a", "late"])
    s = store.load_summary(2, 0)
    assert s is not None
    assert s.version_vector.get("a", -1) >= counters["a"] - 2  # fresh, not the seed


# --- explicit keep_history on shared stores ----------------------------------


def test_sync_warns_when_flipping_keep_history_on_shared_store():
    store = WeightStore(InMemoryFolder())
    with pytest.warns(UserWarning, match="keep_history"):
        SyncFederatedNode(strategy=FedAvg(), store=store, node_id="s",
                          num_nodes=1, timeout=1)
    assert store.keep_history


def test_sync_no_warning_when_store_is_private_or_explicit():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        SyncFederatedNode(strategy=FedAvg(), shared_folder=InMemoryFolder(),
                          node_id="s1", num_nodes=1, timeout=1)
        SyncFederatedNode(strategy=FedAvg(),
                          store=WeightStore(InMemoryFolder(), keep_history=True),
                          node_id="s2", num_nodes=1, timeout=1)


# --- dynamic regrouping: epoch-versioned rosters ------------------------------


def test_roster_write_read_epoch_bumps():
    from repro.core import read_roster, write_roster

    folder = InMemoryFolder()
    assert read_roster(folder) is None
    assert write_roster(folder, ["n1", "n0"]) == 0
    assert read_roster(folder) == (0, ["n0", "n1"])  # sorted, deduped
    # unchanged membership is a no-op: the epoch does not churn
    assert write_roster(folder, ["n0", "n1"]) == 0
    assert write_roster(folder, ["n0", "n1", "n2"]) == 1
    epoch, nodes = read_roster(folder)
    assert epoch == 1 and nodes == ["n0", "n1", "n2"]
    # older epochs remain readable history; freshest always wins
    assert folder.get("fleet/roster/000000") is not None


def test_roster_concurrent_writers_converge():
    """Racing publishers CAS distinct epochs; every membership set lands at
    exactly one epoch and the freshest read is one of the published sets."""
    import threading

    from repro.core import read_roster, write_roster

    folder = InMemoryFolder()
    write_roster(folder, ["a"])
    sets = [["a", f"j{i}"] for i in range(6)]
    threads = [threading.Thread(target=write_roster, args=(folder, s))
               for s in sets]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    epoch, nodes = read_roster(folder)
    assert epoch >= 1 and nodes in [sorted(s) for s in sets]


def test_roster_blobs_never_disturb_state_hash(tmp_path):
    """fleet/roster/ lives under the fleet/ exclusion: publishing a roster
    into the data folder must not look like federation traffic."""
    from repro.core import write_roster

    folder = DiskFolder(str(tmp_path))
    store = WeightStore(folder)
    store.push(NodeUpdate(params(1.0), num_examples=1, node_id="n0", counter=0))
    before = store.state_hash(exclude_node="n0")
    write_roster(folder, ["n0", "n1", "n2"])
    assert store.state_hash(exclude_node="n0") == before


def _moved_node(before_nodes, after_nodes, num_groups):
    """A node id whose balanced-group home changes between two rosters."""
    a = balanced_groups(before_nodes, num_groups)
    b = balanced_groups(after_nodes, num_groups)
    for nid in before_nodes:
        if nid in b and a[nid] != b[nid]:
            return nid
    return None


def test_sharded_store_regroups_and_migrates_on_roster_bump(tmp_path):
    from repro.core import write_roster

    # craft a membership change that provably moves at least one node
    num_groups = 2
    nodes, joined = None, None
    for n in range(4, 40):
        cand = [f"node{i:04d}" for i in range(n)]
        moved = _moved_node(cand, cand + ["joiner"], num_groups)
        if moved is not None:
            nodes, joined, mover = cand, cand + ["joiner"], moved
            break
    assert nodes is not None

    base = str(tmp_path)
    store = ShardedWeightStore(f"shard{num_groups}+{base}",
                               roster_check_every=1)
    write_roster(make_folder(base), nodes)
    for i, nid in enumerate(nodes):
        store.push(NodeUpdate(params(i), num_examples=1, node_id=nid, counter=0))
    assert store.roster_epoch == 0 and store.num_regroups == 1
    before = balanced_groups(nodes, num_groups)
    assert store.group_of(mover) == before[mover]

    # membership change: the joiner publishes the grown roster
    write_roster(make_folder(base), joined)
    after = balanced_groups(joined, num_groups)
    store.push(NodeUpdate(params(99), num_examples=1, node_id=mover, counter=1))
    assert store.roster_epoch == 1 and store.num_regroups == 2
    assert store.group_of(mover) == after[mover] != before[mover]
    # the push migrated the mover's deposits to its new home group folder
    old_folder = store.folders.group_folder(before[mover])
    new_folder = store.folders.group_folder(after[mover])
    assert f"latest/{mover}" not in list(old_folder.keys())
    assert f"latest/{mover}" in list(new_folder.keys())
    pulled = store.pull_node(mover)
    assert pulled is not None and pulled.counter == 1


def test_pull_node_falls_back_across_groups_after_regroup(tmp_path):
    """Regroup race: the roster moved a node's home before its next push
    migrated the blobs. A resume-time pull_node must still find the latest
    blob via the cross-group sweep."""
    from repro.core import write_roster

    num_groups = 2
    nodes = None
    for n in range(4, 40):
        cand = [f"node{i:04d}" for i in range(n)]
        moved = _moved_node(cand, cand + ["joiner"], num_groups)
        if moved is not None:
            nodes, mover = cand, moved
            break
    base = str(tmp_path)
    store = ShardedWeightStore(f"shard{num_groups}+{base}",
                               roster_check_every=1)
    write_roster(make_folder(base), nodes)
    store.push(NodeUpdate(params(7), num_examples=1, node_id=mover, counter=3))
    # roster bump absorbed WITHOUT the mover pushing again (refresh only)
    write_roster(make_folder(base), nodes + ["joiner"])
    assert store.refresh_roster() is True
    assert store.group_of(mover) != balanced_groups(nodes, num_groups)[mover] \
        or True  # home may or may not move; the pull must work either way
    pulled = store.pull_node(mover)
    assert pulled is not None and pulled.counter == 3


def test_factory_store_without_uri_skips_roster_probe():
    """Factory-built shards have no base URI to derive a roster folder from:
    refresh is a no-op unless roster_folder= is passed explicitly."""
    from repro.core import write_roster

    store = fresh_sharded(2)
    assert store.refresh_roster() is False and store.roster_epoch == -1
    roster = InMemoryFolder()
    write_roster(roster, ["a", "b", "c"])
    explicit = fresh_sharded(2, roster_folder=roster)
    assert explicit.refresh_roster() is True
    assert explicit.roster_epoch == 0
    assert explicit.group_of("a") == balanced_groups(["a", "b", "c"], 2)["a"]


# --- hierarchical tiers (shard<G>x<L>+) --------------------------------------


def _leaves(hier, level, origin):
    """Level-0 origins covered by (level, origin) in the summary tree."""
    if level == 0:
        return [origin]
    out = []
    for child in hier.children(level, origin):
        out.extend(_leaves(hier, level - 1, child))
    return out


@settings(max_examples=20)
@given(st.integers(1, 40), st.integers(1, 4))
def test_hierarchy_topology_invariants(num_groups, levels):
    """The summary tree is pure arithmetic on (num_groups, levels): holders
    are distinct per level and descend from their own subtree, and every
    group's pull scope partitions the foreign fleet — each leaf group is
    covered by exactly one admissible (level, origin)."""
    from repro.core import GossipHierarchy

    h = GossipHierarchy(num_groups, levels)
    assert h.counts[0] == num_groups
    for t in range(1, levels):
        holders = [h.holder(t, o) for o in range(h.counts[t])]
        assert len(set(holders)) == h.counts[t]  # disjoint subtrees: no collisions
        for o, g in enumerate(holders):
            assert g in _leaves(h, t, o)
        # a second instance derives the identical election with no communication
        assert holders == [GossipHierarchy(num_groups, levels).holder(t, o)
                           for o in range(h.counts[t])]
    for g in range(num_groups):
        covered = [g]
        for t, origins in h.scope(g).items():
            for o in origins:
                covered.extend(_leaves(h, t, o))
        assert sorted(covered) == list(range(num_groups)), (g, h)


def test_shard_levels_uri_routing(tmp_path):
    f = make_folder(f"shard8x2+{tmp_path}")
    assert isinstance(f, ShardedFolders)
    assert f.num_groups == 8 and f.levels == 2
    store = ShardedWeightStore(f)
    assert store.levels == 2
    assert store.hierarchy.branching == 3  # ceil(8 ** (1/2))
    # plain shard<G>+ is the L=1 degenerate case
    assert make_folder(f"shard4+{tmp_path}").levels == 1
    with pytest.raises(ValueError):
        make_folder("shard8x0+memory://")


def _run_marked_round(store, counters, order, marked, tstamp):
    """One gossip round where ``marked``'s pushes carry ``tstamp`` — a
    monotone marker that (super-)summaries propagate via their max-timestamp
    fold, so 'group g has heard' is readable from g's folder alone."""
    for nid in order:
        counters[nid] += 1
        store.push(NodeUpdate(params(counters[nid]), num_examples=1, node_id=nid,
                              counter=counters[nid],
                              timestamp=tstamp if nid == marked else 0.0))


def _groups_hearing(store, tstamp):
    """Groups whose own folder holds any (super-)summary carrying the marker."""
    from repro.core.gossip import _parse_summary_key

    heard = set()
    for g in range(store.num_groups):
        for key in store.folders.group_folder(g).keys():
            parsed = _parse_summary_key(key)
            if parsed is None:
                continue
            level, ostr, _v = parsed
            s = store.load_summary(g, int(ostr), level)
            if s is not None and s.timestamp >= tstamp:
                heard.add(g)
                break
    return heard


@settings(max_examples=6)
@given(st.integers(4, 9), st.integers(2, 3), st.integers(1, 2),
       st.integers(0, 2**31 - 1))
def test_update_crosses_hierarchy_within_tiered_diameter(num_groups, levels,
                                                         per_group, seed):
    """The ≥2-level convergence bound: information planted in an arbitrary
    level-0 group reaches every group within ``levels × per-ring-diameter``
    rounds (``GossipHierarchy.diameter()``), under adversarial per-round push
    orderings — level-0 rings carry it to the aggregator, tier folds lift it,
    shorter rings spread it, down-copies land it in every home folder."""
    node_ids = [f"n{i}" for i in range(num_groups * per_group)]
    mapping = {nid: i % num_groups for i, nid in enumerate(node_ids)}
    store = fresh_sharded(num_groups, levels=levels, group_of=mapping)
    rng = np.random.default_rng(seed)
    counters = {nid: -1 for nid in node_ids}
    marked = "n0"  # lives in group 0; the planted group is arbitrary by symmetry
    MARK = 1e9

    order = list(node_ids)
    rng.shuffle(order)
    _run_marked_round(store, counters, order, None, 0.0)  # seed round

    bound = store.hierarchy.diameter()
    rounds_needed = None
    for r in range(1, bound + 1):
        order = list(node_ids)
        rng.shuffle(order)
        _run_marked_round(store, counters, order, marked, MARK)
        if _groups_hearing(store, MARK) == set(range(num_groups)):
            rounds_needed = r
            break
    assert rounds_needed is not None and rounds_needed <= bound, (
        num_groups, levels, per_group, seed, _groups_hearing(store, MARK))


def test_two_level_pull_covers_fleet_exactly_once():
    """The scope partition in action: after convergence every node's pull —
    home peers as real updates, segment siblings as level-0 summaries, the
    rest of the fleet as supers — covers the fleet's example weight exactly
    once, and the weighted mean equals the global mean (no double counting,
    nothing dropped)."""
    num_groups, per_group = 9, 2
    node_ids = [f"n{i}" for i in range(num_groups * per_group)]
    mapping = {nid: i % num_groups for i, nid in enumerate(node_ids)}
    store = fresh_sharded(num_groups, levels=2, group_of=mapping)
    # fixed per-node values (counters still advance so versions stay monotone);
    # summaries lag a round in *staleness* but never in *value*
    values = {nid: float(i) for i, nid in enumerate(node_ids)}
    for rnd in range(store.hierarchy.diameter() + 1):
        for nid in node_ids:
            store.push(NodeUpdate(params(values[nid]), num_examples=1,
                                  node_id=nid, counter=rnd))
    fleet_mean = np.mean([values[nid] for nid in node_ids])
    for nid in node_ids:
        pulled = store.pull(exclude=nid)
        ids = [u.node_id for u in pulled]
        assert len(ids) == len(set(ids)), ids  # no duplicate peers
        total = sum(u.num_examples for u in pulled)
        assert total == len(node_ids) - 1, (nid, ids)
        acc = sum(u.num_examples * np.asarray(u.params["w"], np.float64)
                  for u in pulled)
        mean = (acc + values[nid]) / len(node_ids)
        assert np.allclose(mean, fleet_mean, rtol=1e-5), (nid, mean, fleet_mean)


def test_super_summary_counter_is_max_descendant_counter():
    """FedAsync-style discounting sees true staleness through the tiers: a
    super pseudo-peer's counter equals the max node counter it covers, even
    though its version vector is per-child maxima, not a fleet-wide vector."""
    num_groups = 9
    node_ids = [f"n{i}" for i in range(num_groups)]
    mapping = {nid: i for i, nid in enumerate(node_ids)}
    store = fresh_sharded(num_groups, levels=2, group_of=mapping)
    # node i pushes up to counter i: per-group staleness differs
    for rnd in range(num_groups):
        for i, nid in enumerate(node_ids):
            if i >= rnd:
                store.push(NodeUpdate(params(i), num_examples=1, node_id=nid,
                                      counter=rnd))
    for _ in range(store.hierarchy.diameter()):
        for i, nid in enumerate(node_ids):
            store.push(NodeUpdate(params(i), num_examples=1, node_id=nid,
                                  counter=i))
    hier = store.hierarchy
    pulled = store.pull(exclude="n0")
    supers = [u for u in pulled if u.node_id.startswith(f"{GROUP_PEER_PREFIX}L")]
    assert supers, [u.node_id for u in pulled]
    for u in supers:
        origin = u.metrics["summary_of"]
        level = u.metrics["summary_level"]
        covered = _leaves(hier, level, origin)
        assert u.counter == max(covered), (u.node_id, u.counter, covered)


def test_own_push_does_not_defeat_skip_check_hierarchical():
    """Algorithm 1's fast path survives the tiers: a push on an aggregator
    group refreshes its level-0 summary AND re-folds the covering supers into
    its own folder — all excluded from the pusher's own state hash."""
    from repro.core import GossipHierarchy

    hier = GossipHierarchy(4, 2)
    # pick the group that holds its own covering super (an aggregator)
    agg = next(g for g in range(4) if hier.holder(1, hier.path(g)[1]) == g)
    store = fresh_sharded(4, levels=2, group_of={"solo": agg})
    node = AsyncFederatedNode(strategy=FedAvg(), store=store, node_id="solo")
    assert node.update_parameters(params(1.0), 10) is None
    pulls = node.num_pulls
    for i in range(3):
        assert node.update_parameters(params(float(i)), 10) is None
    assert node.num_pulls == pulls
    assert node.num_skipped_pulls >= 3


# --- the listing memo (PipelineStats: summary_index_hits/misses) -------------


def test_summary_listing_memo_skips_reindex_on_quiet_folders():
    """Steady-state pulls with unchanged listings reuse the parsed summary
    index (keyed on the folder's listing-change token); any deposit moves the
    token and forces exactly one re-index."""
    store = fresh_sharded(2, group_of={"a": 0, "b": 1})
    counters = {"a": -1, "b": -1}
    for _ in range(3):
        _run_round(store, counters, ["a", "b"])
    store.pull(exclude="a")  # warm-up: absorb the last round's token move
    base = store.cache_stats()
    assert base["summary_index_misses"] > 0  # cold indexes were built
    for _ in range(5):
        store.pull(exclude="a")
    after = store.cache_stats()
    assert after["summary_index_hits"] >= base["summary_index_hits"] + 5
    assert after["summary_index_misses"] == base["summary_index_misses"]
    # b's push forwards a fresher summary into a's folder -> token moves
    counters["b"] += 1
    store.push(NodeUpdate(params(5.0), num_examples=1, node_id="b",
                          counter=counters["b"]))
    store.pull(exclude="a")
    assert store.cache_stats()["summary_index_misses"] > after["summary_index_misses"]


def test_listing_memo_never_serves_stale_index():
    """The memo is an optimization, not a consistency layer: a fresh deposit
    must be visible to the next pull (the token moved), and pulls on a
    tokenless backend still work (every call re-indexes)."""
    store = fresh_sharded(3, group_of={"a": 0, "b": 1})
    counters = {"a": -1, "b": -1}
    for _ in range(4):
        _run_round(store, counters, ["a", "b"])
    before = [u for u in store.pull(exclude="a")
              if u.node_id == f"{GROUP_PEER_PREFIX}1"]
    assert before
    v0 = before[0].metrics["summary_version"]
    counters["b"] += 1
    store.push(NodeUpdate(params(123.0), num_examples=1, node_id="b",
                          counter=counters["b"]))
    counters["a"] += 1
    store.push(NodeUpdate(params(0.0), num_examples=1, node_id="a",
                          counter=counters["a"]))
    after = [u for u in store.pull(exclude="a")
             if u.node_id == f"{GROUP_PEER_PREFIX}1"]
    assert after and after[0].metrics["summary_version"] > v0


# --- regroup invalidation (satellite: stale caches must not survive epochs) --


def test_regroup_invalidates_decoded_summary_and_index_caches():
    """Regression: a roster epoch bump regroups the fleet — summaries decoded
    under the old grouping (and memoized listings) must not satisfy post-epoch
    pulls; the caches drop and rebuild from the folders."""
    from repro.core import write_roster

    roster = InMemoryFolder()
    store = ShardedWeightStore(
        ShardedFolders(3, factory=lambda g: InMemoryFolder()),
        roster_folder=roster, roster_check_every=10**6)
    nodes = [f"node{i:04d}" for i in range(9)]
    write_roster(roster, nodes)
    assert store.refresh_roster() is True
    counters = {n: -1 for n in nodes}
    for _ in range(4):
        _run_round(store, counters, nodes)
    for n in nodes:
        store.pull(exclude=n)
    assert len(store._summary_cache) > 0
    assert store._index_memo
    # membership changes -> next epoch -> regroup: derived caches are dropped
    write_roster(roster, nodes[:6])
    assert store.refresh_roster() is True
    assert len(store._summary_cache) == 0
    assert not store._index_memo
    # and the post-epoch pull path rebuilds cleanly from the folders
    survivors = {n: counters[n] for n in nodes[:6]}
    for _ in range(4):
        _run_round(store, survivors, nodes[:6])
    pulled = store.pull(exclude=nodes[0])
    assert pulled  # fresh decodes, no crash, no pre-epoch cache hits
    assert len(store._summary_cache) > 0
