import threading
import time

import numpy as np
import pytest

from repro.core import (
    AsyncFederatedNode,
    FederationTimeout,
    InMemoryFolder,
    SyncFederatedNode,
    run_threaded,
)
from repro.core.strategies import FedAvg


def params(v):
    return {"w": np.full((4,), float(v), np.float32)}


def test_async_first_node_keeps_training():
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=InMemoryFolder(), node_id="a")
    assert node.update_parameters(params(1.0), 10) is None
    assert node.num_pushes == 1


def test_async_two_nodes_aggregate():
    folder = InMemoryFolder()
    a = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="a")
    b = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="b")
    assert a.update_parameters(params(0.0), 10) is None
    out = b.update_parameters(params(2.0), 10)
    assert out is not None and np.allclose(out["w"], 1.0)


def test_async_state_hash_skips_redundant_pull():
    folder = InMemoryFolder()
    a = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="a")
    b = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="b")
    a.update_parameters(params(0.0), 10)
    b.update_parameters(params(2.0), 10)
    pulls_before = b.num_pulls
    # nothing changed except b's own deposit → hash check short-circuits
    assert b.update_parameters(params(3.0), 10) is None
    assert b.num_pulls == pulls_before
    assert b.num_skipped_pulls >= 1


def test_async_sees_fresher_peer_weights():
    folder = InMemoryFolder()
    a = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="a")
    b = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="b")
    a.update_parameters(params(0.0), 10)
    b.update_parameters(params(2.0), 10)
    a.update_parameters(params(4.0), 10)  # a deposits round 1
    out = b.update_parameters(params(2.0), 10)
    assert out is not None and np.allclose(out["w"], 3.0)  # sees a's round-1 weights


def test_sync_barrier_identical_results():
    folder = InMemoryFolder()
    outs = {}

    def client(nid, val):
        node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id=nid,
                                 num_nodes=3, timeout=10)
        outs[nid] = node.update_parameters(params(val), 10)

    res = run_threaded([
        lambda: client("a", 0.0), lambda: client("b", 3.0), lambda: client("c", 6.0)
    ])
    assert all(r.error is None for r in res)
    for nid in ("a", "b", "c"):
        assert np.allclose(outs[nid]["w"], 3.0)


def test_sync_round_isolation_under_speed_skew():
    """A fast node racing ahead must not corrupt a slow node's round-t set."""
    folder = InMemoryFolder()
    outs = {}

    def fast():
        node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="fast",
                                 num_nodes=2, timeout=10)
        outs["fast0"] = node.update_parameters(params(2.0), 10)
        outs["fast1"] = node.update_parameters(params(10.0), 10)

    def slow():
        node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="slow",
                                 num_nodes=2, timeout=10)
        time.sleep(0.2)
        outs["slow0"] = node.update_parameters(params(4.0), 10)
        outs["slow1"] = node.update_parameters(params(10.0), 10)

    res = run_threaded([fast, slow])
    assert all(r.error is None for r in res), [r.traceback for r in res]
    assert np.allclose(outs["fast0"]["w"], 3.0)
    assert np.allclose(outs["slow0"]["w"], 3.0)  # round-0 blobs, not fast's round-1


def test_sync_timeout_on_missing_peer():
    node = SyncFederatedNode(strategy=FedAvg(), shared_folder=InMemoryFolder(),
                             node_id="lonely", num_nodes=2, timeout=0.3)
    with pytest.raises(FederationTimeout):
        node.update_parameters(params(1.0), 10)


def test_async_node_survives_peer_crash():
    """The async robustness claim: a crashed peer never blocks others."""
    folder = InMemoryFolder()

    def crasher():
        node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="crash")
        node.update_parameters(params(1.0), 10)
        raise RuntimeError("injected OOM")

    def survivor():
        node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="ok")
        results = []
        for i in range(3):
            time.sleep(0.05)
            results.append(node.update_parameters(params(float(i)), 10))
        return results

    res = run_threaded([crasher, survivor])
    assert res[0].error is not None
    assert res[1].error is None
    assert any(r is not None for r in res[1].result)  # still aggregated crash's deposit


def test_sync_timeout_deadline_uses_injected_clock():
    """The barrier deadline must run on the node's injected clock (satellite
    fix: it used time.monotonic() directly), so simulated-clock harnesses can
    age the barrier without real sleeping: a 500-virtual-second timeout with
    a fast virtual clock must raise in well under a real second."""
    t = {"now": 0.0}

    def clock():
        t["now"] += 100.0
        return t["now"]

    node = SyncFederatedNode(num_nodes=2, timeout=500.0, poll_interval=0.0,
                             shared_folder=InMemoryFolder(), node_id="solo",
                             clock=clock)
    t0 = time.monotonic()
    with pytest.raises(FederationTimeout):
        node.update_parameters(params(1.0), num_examples=1)
    assert time.monotonic() - t0 < 5.0  # virtual deadline, not 500 real s
    assert t["now"] > 500.0  # the virtual clock is what expired
