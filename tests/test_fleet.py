"""Fleet launcher + chaos soak harness (``repro.core.fleet``).

Covers the control plane (spec round-trips through the store, slot-claim
mutual exclusion under concurrent workers), the seeded chaos schedule's
determinism, and the soak itself: SIGKILL-restart-resume across multiple
worker invocations converging to one fleet state hash.
"""
import threading
import time

import numpy as np
import pytest

from _hyp import given, settings, strategies as st

from repro.core import (
    ChaosSpec,
    DiskFolder,
    FleetSpec,
    InMemoryFolder,
    chaos_schedule,
    claim_slots,
    run_fleet_local,
    run_worker,
)
from repro.core.fleet import (
    SPEC_KEY,
    assemble_report,
    control_folder,
    fleet_control_uri,
    read_spec,
    write_spec,
)
from repro.core.serialize import peek_meta


def _spec(tmp_path, **kw):
    defaults = dict(store_uri=str(tmp_path), num_nodes=4, rounds=4,
                    runner="thread", param_size=32, round_sleep=0.01,
                    settle=0.2, result_timeout=60.0)
    defaults.update(kw)
    return FleetSpec(**defaults)


# --- spec round-trip through the store ---------------------------------------


def test_fleet_spec_roundtrip_through_store(tmp_path):
    spec = _spec(tmp_path, transport="delta(chain=4)",
                 chaos=ChaosSpec(seed=3, kills=1, stalls=1))
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    # the deposit is a self-describing fleet blob, dispatchable by meta alone
    assert peek_meta(control.get(SPEC_KEY))["fleet_of"] == "spec"
    loaded = read_spec(control)
    assert loaded.to_dict() == spec.to_dict()
    assert loaded.chaos.kills == 1 and loaded.transport == "delta(chain=4)"
    # JSON round-trip too (the CLI's serialization path)
    assert FleetSpec.from_json(spec.to_json()).to_dict() == spec.to_dict()


def test_read_spec_times_out_on_empty_folder():
    with pytest.raises(TimeoutError):
        read_spec(InMemoryFolder(), timeout=0.05, poll=0.01)


def test_fleet_spec_validation(tmp_path):
    with pytest.raises(ValueError):
        _spec(tmp_path, runner="fiber")
    with pytest.raises(ValueError):
        _spec(tmp_path, rounds=1, chaos=ChaosSpec(kills=1))
    with pytest.raises(ValueError):
        _spec(tmp_path, num_nodes=2, chaos=ChaosSpec(kills=2, stalls=1))


def test_fleet_control_uri_strips_wrappers():
    assert fleet_control_uri("shard4+cache+/mnt/x") == "/mnt/x"
    assert fleet_control_uri("cache+/mnt/x") == "/mnt/x"
    assert fleet_control_uri("/mnt/x") == "/mnt/x"
    with pytest.raises(ValueError):
        fleet_control_uri("memory://")


# --- slot-claim mutual exclusion ---------------------------------------------


@settings(max_examples=15, deadline=None)  # thread scheduling outruns any deadline
@given(st.integers(2, 6), st.integers(1, 8), st.integers(0, 10_000))
def test_claim_mutual_exclusion_under_concurrent_workers(workers, slots, seed):
    """However many workers race, the claims always partition the slot space:
    no slot is owned twice, every slot is owned once the dust settles, and a
    worker re-claiming (restart under the same id) gets exactly its own slots
    back."""
    spec = FleetSpec(store_uri="/unused", num_nodes=slots, rounds=2,
                     runner="thread")
    control = InMemoryFolder()
    claimed: dict[str, list[int]] = {}
    barrier = threading.Barrier(workers)

    def worker(wid):
        barrier.wait()  # maximize contention
        claimed[wid] = claim_slots(control, spec, wid)

    threads = [threading.Thread(target=worker, args=(f"w{i}-{seed}",))
               for i in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    owned = [s for slots_ in claimed.values() for s in slots_]
    assert sorted(owned) == list(range(slots))  # partition: disjoint + complete
    # reclaim: same worker id gets the same slots, nothing more
    for wid, mine in claimed.items():
        assert claim_slots(control, spec, wid) == mine


def test_diskfolder_put_if_absent_single_winner(tmp_path):
    """link(2)-based create: exactly one of N racing threads wins the key."""
    folder = DiskFolder(str(tmp_path))
    wins = []
    barrier = threading.Barrier(8)

    def race(i):
        barrier.wait()
        if folder.put_if_absent("fleet/claim/0000", f"w{i}".encode()):
            wins.append(i)

    threads = [threading.Thread(target=race, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1
    assert folder.get("fleet/claim/0000") == f"w{wins[0]}".encode()
    # a later put_if_absent still loses; plain put still overwrites
    assert not folder.put_if_absent("fleet/claim/0000", b"late")
    folder.put("fleet/claim/0000", b"force")
    assert folder.get("fleet/claim/0000") == b"force"


def test_max_slots_caps_claims(tmp_path):
    spec = _spec(tmp_path, num_nodes=6)
    control = InMemoryFolder()
    a = claim_slots(control, spec, "a", max_slots=4)
    b = claim_slots(control, spec, "b", max_slots=4)
    assert a == [0, 1, 2, 3] and b == [4, 5]


# --- seeded chaos schedule ---------------------------------------------------


def test_chaos_schedule_deterministic(tmp_path):
    spec = _spec(tmp_path, num_nodes=16, rounds=6,
                 chaos=ChaosSpec(seed=11, kills=3, stalls=2))
    first = chaos_schedule(spec)
    again = chaos_schedule(FleetSpec.from_dict(spec.to_dict()))
    assert first == again  # pure function of the spec — any host, any order
    kills = {n for n, evs in first.items() if any(e.kind == "kill" for e in evs)}
    stalls = {n for n, evs in first.items() if any(e.kind == "stall" for e in evs)}
    assert len(kills) == 3 and len(stalls) == 2
    assert not kills & stalls  # victims drawn without replacement
    for evs in first.values():
        for ev in evs:
            if ev.kind == "kill":
                # must die after >=1 push (a blob to resume from) and before
                # finishing its rounds
                assert 1 <= ev.after_pushes <= spec.rounds - 1


def test_chaos_schedule_seed_sensitivity(tmp_path):
    base = _spec(tmp_path, num_nodes=16, rounds=6)
    schedules = {
        seed: chaos_schedule(_spec(tmp_path, num_nodes=16, rounds=6,
                                   chaos=ChaosSpec(seed=seed, kills=3)))
        for seed in range(6)
    }
    victim_sets = {s: frozenset(sched) for s, sched in schedules.items()}
    # different seeds must actually move the victims around (not necessarily
    # pairwise distinct — 16 choose 3 collisions happen — but not constant)
    assert len(set(victim_sets.values())) > 1
    assert chaos_schedule(base) == {}  # no chaos configured -> empty schedule


# --- the soak ----------------------------------------------------------------


def test_thread_soak_8_nodes_2_workers_chaos(tmp_path):
    """≥8 nodes across ≥2 workers over a shared folder: seeded kills + stalls,
    every victim resumes, every worker computes the same fleet hash."""
    spec = _spec(tmp_path, num_nodes=8, rounds=5,
                 chaos=ChaosSpec(seed=7, kills=2, stalls=1,
                                 restart_after=0.1, stall_duration=0.2))
    report = run_fleet_local(spec, num_workers=2)
    assert report.complete and report.converged and report.recovered
    assert report.passed, report.summary()
    assert report.crashes_injected == 2 and report.restarts == 2
    assert len(report.fleet_hashes) == 2
    assert len(set(report.fleet_hashes.values())) == 1
    for victim in report.victims:
        assert report.resumed[victim] is True
        assert report.recovery_latency[victim] >= 0.0
    for nid, rounds in report.rounds_completed.items():
        assert rounds >= spec.rounds, (nid, rounds)
    # two workers actually partitioned the fleet
    assert sorted(report.claims) == list(range(8))
    assert len(set(report.claims.values())) == 2
    # pipeline stats rolled up across every node's transport counters
    assert report.pipeline_stats["bytes_written"] > 0
    assert report.rounds_per_sec > 0


def test_soak_report_fails_without_recovery(tmp_path):
    """A victim that never comes back must fail the soak: kill one node's
    result blob out of a passing fleet and the report flips to not-passed."""
    spec = _spec(tmp_path, num_nodes=4, rounds=4,
                 chaos=ChaosSpec(seed=1, kills=1, restart_after=0.05))
    report = run_fleet_local(spec, num_workers=2)
    assert report.passed
    control = control_folder(spec.store_uri)
    victim = report.victims[0]
    control.delete(f"fleet/result/{victim}")
    broken = assemble_report(control, spec)
    assert not broken.complete and not broken.passed


def test_fleet_blobs_never_disturb_federation_hashes(tmp_path):
    """Control traffic (spec/claims/heartbeats/results) is excluded from the
    federation state hash — nodes sharing the folder with the control plane
    must not re-pull on every heartbeat."""
    from repro.core import NodeUpdate, WeightStore

    spec = _spec(tmp_path)
    store = WeightStore(DiskFolder(str(tmp_path)))
    store.push(NodeUpdate({"w": np.ones(4, np.float32)}, num_examples=1,
                          node_id="n0", counter=0))
    before = store.state_hash(exclude_node="n0")
    write_spec(control_folder(spec.store_uri), spec)
    claim_slots(control_folder(spec.store_uri), spec, "w0")
    assert store.state_hash(exclude_node="n0") == before
    assert store.state_hash() == store.state_hash()


# --- the real thing: SIGKILL + restart across worker invocations -------------


@pytest.mark.multiprocess
def test_process_soak_sigkill_restart_resume_two_workers(tmp_path):
    """Two worker invocations (as two concurrent run_worker calls, exactly
    what two `repro.fleet worker` shells do), nodes as real OS processes, one
    seeded SIGKILL victim: the victim is killed mid-round, respawned, and its
    restarted incarnation reports resumed=True; both workers agree on the
    fleet hash."""
    spec = _spec(tmp_path, num_nodes=4, rounds=4, runner="process",
                 round_sleep=0.05, settle=0.5, result_timeout=120.0,
                 chaos=ChaosSpec(seed=7, kills=1, restart_after=0.3,
                                 kill_grace=60.0))
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    reports = {}

    def worker(wid):
        reports[wid] = run_worker(spec=spec, control=control, worker_id=wid,
                                  max_slots=2, timeout=180.0)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in ("hostA", "hostB")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=240.0)
    assert all(not t.is_alive() for t in threads)

    report = assemble_report(control, spec)
    assert report.complete and report.converged
    assert report.crashes_injected == 1 and report.restarts == 1
    assert report.passed, report.summary()
    victim = report.victims[0]
    assert report.resumed[victim] is True
    # the restarted node continued its counter, it did not start over
    assert report.results[victim]["start_counter"] > 0
    assert report.results[victim]["final_counter"] >= spec.rounds
    assert report.recovery_latency[victim] > 0.0
    # both workers hashed the same quiesced store, independently
    assert set(reports) == {"hostA", "hostB"}
    hashes = {r.fleet_state_hash for r in reports.values()}
    assert len(hashes) == 1 and None not in hashes


# --- the CLI -----------------------------------------------------------------


def test_fleet_cli_init_workers_report(tmp_path, capsys):
    """The documented multi-host flow, driven through the argparse entry
    point: init, two worker invocations, report --assert-passed."""
    from repro.fleet import main

    store = str(tmp_path)
    assert main(["init", "--store", store, "--nodes", "4", "--rounds", "3",
                 "--runner", "thread", "--round-sleep", "0.01",
                 "--settle", "0.2", "--chaos-kills", "1", "--seed", "2",
                 "--param-size", "32"]) == 0
    codes = {}

    def worker(wid):
        codes[wid] = main(["worker", "--store", store, "--worker-id", wid,
                           "--max-slots", "2"])

    threads = [threading.Thread(target=worker, args=(w,)) for w in ("a", "b")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert codes == {"a": 0, "b": 0}
    assert main(["report", "--store", store, "--assert-passed"]) == 0
    out = capsys.readouterr().out
    assert "passed: True" in out
