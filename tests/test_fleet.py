"""Fleet launcher + chaos soak harness (``repro.core.fleet``).

Covers the control plane (spec round-trips through the store, slot-claim
mutual exclusion under concurrent workers), the seeded chaos schedule's
determinism, and the soak itself: SIGKILL-restart-resume across multiple
worker invocations converging to one fleet state hash.
"""
import threading
import time

import numpy as np
import pytest

from _hyp import given, settings, strategies as st

from repro.core import (
    ChaosSpec,
    DiskFolder,
    FleetSpec,
    InMemoryFolder,
    chaos_schedule,
    claim_slots,
    run_fleet_local,
    run_worker,
)
from repro.core.fleet import (
    SPEC_KEY,
    assemble_report,
    control_folder,
    fleet_control_uri,
    read_spec,
    write_spec,
)
from repro.core.serialize import peek_meta


def _spec(tmp_path, **kw):
    defaults = dict(store_uri=str(tmp_path), num_nodes=4, rounds=4,
                    runner="thread", param_size=32, round_sleep=0.01,
                    settle=0.2, result_timeout=60.0)
    defaults.update(kw)
    return FleetSpec(**defaults)


# --- spec round-trip through the store ---------------------------------------


def test_fleet_spec_roundtrip_through_store(tmp_path):
    spec = _spec(tmp_path, transport="delta(chain=4)",
                 chaos=ChaosSpec(seed=3, kills=1, stalls=1))
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    # the deposit is a self-describing fleet blob, dispatchable by meta alone
    assert peek_meta(control.get(SPEC_KEY))["fleet_of"] == "spec"
    loaded = read_spec(control)
    assert loaded.to_dict() == spec.to_dict()
    assert loaded.chaos.kills == 1 and loaded.transport == "delta(chain=4)"
    # JSON round-trip too (the CLI's serialization path)
    assert FleetSpec.from_json(spec.to_json()).to_dict() == spec.to_dict()


def test_read_spec_times_out_on_empty_folder():
    with pytest.raises(TimeoutError):
        read_spec(InMemoryFolder(), timeout=0.05, poll=0.01)


def test_fleet_spec_validation(tmp_path):
    with pytest.raises(ValueError):
        _spec(tmp_path, runner="fiber")
    with pytest.raises(ValueError):
        _spec(tmp_path, rounds=1, chaos=ChaosSpec(kills=1))
    with pytest.raises(ValueError):
        _spec(tmp_path, num_nodes=2, chaos=ChaosSpec(kills=2, stalls=1))


def test_fleet_control_uri_strips_wrappers():
    assert fleet_control_uri("shard4+cache+/mnt/x") == "/mnt/x"
    assert fleet_control_uri("cache+/mnt/x") == "/mnt/x"
    assert fleet_control_uri("/mnt/x") == "/mnt/x"
    with pytest.raises(ValueError):
        fleet_control_uri("memory://")


# --- slot-claim mutual exclusion ---------------------------------------------


@settings(max_examples=15, deadline=None)  # thread scheduling outruns any deadline
@given(st.integers(2, 6), st.integers(1, 8), st.integers(0, 10_000))
def test_claim_mutual_exclusion_under_concurrent_workers(workers, slots, seed):
    """However many workers race, the claims always partition the slot space:
    no slot is owned twice, every slot is owned once the dust settles, and a
    worker re-claiming (restart under the same id) gets exactly its own slots
    back."""
    spec = FleetSpec(store_uri="/unused", num_nodes=slots, rounds=2,
                     runner="thread")
    control = InMemoryFolder()
    claimed: dict[str, list[int]] = {}
    barrier = threading.Barrier(workers)

    def worker(wid):
        barrier.wait()  # maximize contention
        claimed[wid] = claim_slots(control, spec, wid)

    threads = [threading.Thread(target=worker, args=(f"w{i}-{seed}",))
               for i in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    owned = [s for slots_ in claimed.values() for s in slots_]
    assert sorted(owned) == list(range(slots))  # partition: disjoint + complete
    # reclaim: same worker id gets the same slots, nothing more
    for wid, mine in claimed.items():
        assert claim_slots(control, spec, wid) == mine


def test_diskfolder_put_if_absent_single_winner(tmp_path):
    """link(2)-based create: exactly one of N racing threads wins the key."""
    folder = DiskFolder(str(tmp_path))
    wins = []
    barrier = threading.Barrier(8)

    def race(i):
        barrier.wait()
        if folder.put_if_absent("fleet/claim/0000", f"w{i}".encode()):
            wins.append(i)

    threads = [threading.Thread(target=race, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1
    assert folder.get("fleet/claim/0000") == f"w{wins[0]}".encode()
    # a later put_if_absent still loses; plain put still overwrites
    assert not folder.put_if_absent("fleet/claim/0000", b"late")
    folder.put("fleet/claim/0000", b"force")
    assert folder.get("fleet/claim/0000") == b"force"


def test_max_slots_caps_claims(tmp_path):
    spec = _spec(tmp_path, num_nodes=6)
    control = InMemoryFolder()
    a = claim_slots(control, spec, "a", max_slots=4)
    b = claim_slots(control, spec, "b", max_slots=4)
    assert a == [0, 1, 2, 3] and b == [4, 5]


# --- seeded chaos schedule ---------------------------------------------------


def test_chaos_schedule_deterministic(tmp_path):
    spec = _spec(tmp_path, num_nodes=16, rounds=6,
                 chaos=ChaosSpec(seed=11, kills=3, stalls=2))
    first = chaos_schedule(spec)
    again = chaos_schedule(FleetSpec.from_dict(spec.to_dict()))
    assert first == again  # pure function of the spec — any host, any order
    kills = {n for n, evs in first.items() if any(e.kind == "kill" for e in evs)}
    stalls = {n for n, evs in first.items() if any(e.kind == "stall" for e in evs)}
    assert len(kills) == 3 and len(stalls) == 2
    assert not kills & stalls  # victims drawn without replacement
    for evs in first.values():
        for ev in evs:
            if ev.kind == "kill":
                # must die after >=1 push (a blob to resume from) and before
                # finishing its rounds
                assert 1 <= ev.after_pushes <= spec.rounds - 1


def test_chaos_schedule_seed_sensitivity(tmp_path):
    base = _spec(tmp_path, num_nodes=16, rounds=6)
    schedules = {
        seed: chaos_schedule(_spec(tmp_path, num_nodes=16, rounds=6,
                                   chaos=ChaosSpec(seed=seed, kills=3)))
        for seed in range(6)
    }
    victim_sets = {s: frozenset(sched) for s, sched in schedules.items()}
    # different seeds must actually move the victims around (not necessarily
    # pairwise distinct — 16 choose 3 collisions happen — but not constant)
    assert len(set(victim_sets.values())) > 1
    assert chaos_schedule(base) == {}  # no chaos configured -> empty schedule


# --- the soak ----------------------------------------------------------------


def test_thread_soak_8_nodes_2_workers_chaos(tmp_path):
    """≥8 nodes across ≥2 workers over a shared folder: seeded kills + stalls,
    every victim resumes, every worker computes the same fleet hash."""
    spec = _spec(tmp_path, num_nodes=8, rounds=5,
                 chaos=ChaosSpec(seed=7, kills=2, stalls=1,
                                 restart_after=0.1, stall_duration=0.2))
    report = run_fleet_local(spec, num_workers=2)
    assert report.complete and report.converged and report.recovered
    assert report.passed, report.summary()
    assert report.crashes_injected == 2 and report.restarts == 2
    assert len(report.fleet_hashes) == 2
    assert len(set(report.fleet_hashes.values())) == 1
    for victim in report.victims:
        assert report.resumed[victim] is True
        assert report.recovery_latency[victim] >= 0.0
    for nid, rounds in report.rounds_completed.items():
        assert rounds >= spec.rounds, (nid, rounds)
    # two workers actually partitioned the fleet
    assert sorted(report.claims) == list(range(8))
    assert len(set(report.claims.values())) == 2
    # pipeline stats rolled up across every node's transport counters
    assert report.pipeline_stats["bytes_written"] > 0
    assert report.rounds_per_sec > 0


def test_soak_report_fails_without_recovery(tmp_path):
    """A victim that never comes back must fail the soak: kill one node's
    result blob out of a passing fleet and the report flips to not-passed."""
    spec = _spec(tmp_path, num_nodes=4, rounds=4,
                 chaos=ChaosSpec(seed=1, kills=1, restart_after=0.05))
    report = run_fleet_local(spec, num_workers=2)
    assert report.passed
    control = control_folder(spec.store_uri)
    victim = report.victims[0]
    control.delete(f"fleet/result/{victim}")
    broken = assemble_report(control, spec)
    assert not broken.complete and not broken.passed


def test_fleet_blobs_never_disturb_federation_hashes(tmp_path):
    """Control traffic (spec/claims/heartbeats/results) is excluded from the
    federation state hash — nodes sharing the folder with the control plane
    must not re-pull on every heartbeat."""
    from repro.core import NodeUpdate, WeightStore

    spec = _spec(tmp_path)
    store = WeightStore(DiskFolder(str(tmp_path)))
    store.push(NodeUpdate({"w": np.ones(4, np.float32)}, num_examples=1,
                          node_id="n0", counter=0))
    before = store.state_hash(exclude_node="n0")
    write_spec(control_folder(spec.store_uri), spec)
    claim_slots(control_folder(spec.store_uri), spec, "w0")
    assert store.state_hash(exclude_node="n0") == before
    assert store.state_hash() == store.state_hash()


# --- the real thing: SIGKILL + restart across worker invocations -------------


@pytest.mark.multiprocess
def test_process_soak_sigkill_restart_resume_two_workers(tmp_path):
    """Two worker invocations (as two concurrent run_worker calls, exactly
    what two `repro.fleet worker` shells do), nodes as real OS processes, one
    seeded SIGKILL victim: the victim is killed mid-round, respawned, and its
    restarted incarnation reports resumed=True; both workers agree on the
    fleet hash."""
    spec = _spec(tmp_path, num_nodes=4, rounds=4, runner="process",
                 round_sleep=0.05, settle=0.5, result_timeout=120.0,
                 chaos=ChaosSpec(seed=7, kills=1, restart_after=0.3,
                                 kill_grace=60.0))
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    reports = {}

    def worker(wid):
        reports[wid] = run_worker(spec=spec, control=control, worker_id=wid,
                                  max_slots=2, timeout=180.0)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in ("hostA", "hostB")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=240.0)
    assert all(not t.is_alive() for t in threads)

    report = assemble_report(control, spec)
    assert report.complete and report.converged
    assert report.crashes_injected == 1 and report.restarts == 1
    assert report.passed, report.summary()
    victim = report.victims[0]
    assert report.resumed[victim] is True
    # the restarted node continued its counter, it did not start over
    assert report.results[victim]["start_counter"] > 0
    assert report.results[victim]["final_counter"] >= spec.rounds
    assert report.recovery_latency[victim] > 0.0
    # both workers hashed the same quiesced store, independently
    assert set(reports) == {"hostA", "hostB"}
    hashes = {r.fleet_state_hash for r in reports.values()}
    assert len(hashes) == 1 and None not in hashes


# --- the CLI -----------------------------------------------------------------


def test_fleet_cli_init_workers_report(tmp_path, capsys):
    """The documented multi-host flow, driven through the argparse entry
    point: init, two worker invocations, report --assert-passed."""
    from repro.fleet import main

    store = str(tmp_path)
    assert main(["init", "--store", store, "--nodes", "4", "--rounds", "3",
                 "--runner", "thread", "--round-sleep", "0.01",
                 "--settle", "0.2", "--chaos-kills", "1", "--seed", "2",
                 "--param-size", "32"]) == 0
    codes = {}

    def worker(wid):
        codes[wid] = main(["worker", "--store", store, "--worker-id", wid,
                           "--max-slots", "2"])

    threads = [threading.Thread(target=worker, args=(w,)) for w in ("a", "b")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert codes == {"a": 0, "b": 0}
    assert main(["report", "--store", store, "--assert-passed"]) == 0
    out = capsys.readouterr().out
    assert "passed: True" in out


# --- leased claims + crash adoption ------------------------------------------


def test_lease_claim_expiry_and_adoption_roundtrip(tmp_path):
    """The elastic-membership lifecycle at API level: a claim is a lease; a
    silent worker's lease lapses; a second worker adopts the slot at the next
    epoch; the original worker cannot sneak back in at the stale epoch."""
    from repro.core import claim_leases, lease_fresh, read_lease_index

    spec = _spec(tmp_path, num_nodes=2, lease_ttl=0.2)
    control = InMemoryFolder()
    first = claim_leases(control, spec, "mortal")
    assert first == {0: 0, 1: 0}  # founding claims are epoch 0
    index = read_lease_index(control)
    assert all(epoch == 0 and lease_fresh(payload)
               for epoch, payload in index.values())
    time.sleep(0.3)  # nobody refreshes: every lease lapses
    assert not any(lease_fresh(p) for _e, p in read_lease_index(control).values())
    second = claim_leases(control, spec, "adopter")
    assert second == {0: 1, 1: 1}  # adoption bumps the epoch
    index = read_lease_index(control)
    assert all(payload["worker"] == "adopter" for _e, payload in index.values())
    # the original worker finds fresh foreign leases and gets nothing
    assert claim_leases(control, spec, "mortal") == {}


def test_own_expired_lease_is_readopted_at_next_epoch(tmp_path):
    """A worker re-claiming its OWN lapsed lease must still go through the
    epoch-bump CAS — blind refresh at the stale epoch could split-brain with
    a concurrent foreign adopter."""
    from repro.core import claim_leases

    spec = _spec(tmp_path, num_nodes=1, lease_ttl=0.15)
    control = InMemoryFolder()
    assert claim_leases(control, spec, "w") == {0: 0}
    time.sleep(0.25)
    assert claim_leases(control, spec, "w") == {0: 1}


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_exactly_one_adopter_wins_each_epoch(adopters, seed):
    """Adversarial adoption race: N workers observe the same expired lease
    concurrently and all try to adopt. The epoch key is write-once, so
    exactly one wins — no interleaving can mint two owners."""
    from repro.core import try_adopt
    from repro.core.fleet import lease_key
    from repro.core.serialize import serialize_fleet_blob

    spec = FleetSpec(store_uri="/unused", num_nodes=1, rounds=2,
                     runner="thread", lease_ttl=0.1)
    control = InMemoryFolder()
    control.put(lease_key("node0000", 0), serialize_fleet_blob("lease", {
        "worker": "ghost", "slot": 0, "node_id": "node0000", "epoch": 0,
        "deadline": time.time() - 60.0, "time": time.time() - 120.0}))
    winners: list[str] = []
    barrier = threading.Barrier(adopters)

    def race(wid):
        barrier.wait()
        if try_adopt(control, spec, wid, "node0000", 0, 1):
            winners.append(wid)

    threads = [threading.Thread(target=race, args=(f"w{i}-{seed}",))
               for i in range(adopters)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(winners) == 1


def test_diskfolder_adoption_race_single_winner(tmp_path):
    """Same race over DiskFolder: the link(2) CAS is what guarantees a single
    adopter on a real shared mount, so exercise exactly that code path."""
    from repro.core import try_adopt
    from repro.core.fleet import lease_key
    from repro.core.serialize import serialize_fleet_blob

    spec = _spec(tmp_path, num_nodes=1, lease_ttl=0.1)
    control = DiskFolder(str(tmp_path / "control"))
    control.put(lease_key("node0000", 0), serialize_fleet_blob("lease", {
        "worker": "ghost", "slot": 0, "node_id": "node0000", "epoch": 0,
        "deadline": time.time() - 60.0, "time": time.time() - 120.0}))
    winners: list[str] = []
    barrier = threading.Barrier(8)

    def race(i):
        barrier.wait()
        if try_adopt(control, spec, f"w{i}", "node0000", 0, 1):
            winners.append(f"w{i}")

    threads = [threading.Thread(target=race, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(winners) == 1
    # stale intermediate epochs are GC'd by the winner; epoch 0 (the founding
    # record) survives for victim ranking and workers_lost accounting
    keys = [k for k in control.keys() if k.startswith("fleet/lease/")]
    assert sorted(keys) == [lease_key("node0000", 0), lease_key("node0000", 1)]


def test_worker_kill_victims_deterministic(tmp_path):
    """Victim selection is a pure function of the store's founding leases and
    the seed — every host computes the same victim list with no messages."""
    from repro.core import claim_leases, worker_kill_victims

    spec = _spec(tmp_path, num_nodes=6, lease_ttl=30.0,
                 chaos=ChaosSpec(seed=3, kill_workers=1))
    control = InMemoryFolder()
    for wid in ("hostA", "hostB", "hostC"):
        claim_leases(control, spec, wid, max_slots=2)
    first = worker_kill_victims(control, spec.chaos)
    assert len(first) == 1 and first[0] in {"hostA", "hostB", "hostC"}
    assert worker_kill_victims(control, spec.chaos) == first
    # more victims requested than workers exist -> every founder is drawn
    assert len(worker_kill_victims(
        control, ChaosSpec(seed=3, kill_workers=99))) == 3
    assert worker_kill_victims(control, ChaosSpec(seed=3)) == []


# --- churn soak: worker death mid-soak, survivors adopt ----------------------


def test_churn_soak_worker_death_and_adoption(tmp_path):
    """The tentpole end-to-end: 3 workers, one drawn victim dies whole
    mid-soak (its clients abort, its leases lapse), the survivors adopt every
    stranded slot, resume the nodes, and still agree on one fleet hash."""
    spec = _spec(tmp_path, num_nodes=6, rounds=6, round_sleep=0.05,
                 lease_ttl=0.8, result_timeout=60.0,
                 chaos=ChaosSpec(seed=5, kill_workers=1,
                                 kill_workers_after=(1, 3)))
    report = run_fleet_local(spec, num_workers=3)
    assert report.passed, report.summary()
    assert len(report.workers_lost) == 1
    assert report.stranded, "the dead worker must have stranded its slots"
    for nid in report.stranded:
        assert report.adopted[nid] is True
        assert report.results[nid]["lease_epoch"] >= 1
    assert report.adoption_latency, "adopters must report adoption latency"
    assert all(lat >= 0.0 for lat in report.adoption_latency.values())
    # exactly the two survivors report, and they agree on the hash
    assert len(report.fleet_hashes) == 2
    assert len(set(report.fleet_hashes.values())) == 1
    # the summary carries the churn line the CI tier greps for
    assert "adopted" in report.summary()


def test_adopter_result_survives_split_brain_deposit_race(tmp_path):
    """A lease can lapse under a LIVE worker (heartbeat starvation on an
    oversubscribed host, not death); an adopter then double-drives the node.
    Whichever driver deposits last, the churn ledger must read adopted=True
    for the stranded lease: epoch-0 deposits never clobber an adopter's."""
    from repro.core.fleet import _RESULT_PREFIX, _read_fleet_blob, _soak_client

    spec = _spec(tmp_path, num_nodes=1, rounds=2, round_sleep=0.0)
    control = control_folder(spec.store_uri)
    nid = spec.node_id(0)

    # adopter deposits first; the original (epoch-0) driver finishes later
    # and must keep the adopter's record
    _soak_client(spec.to_dict(), 0, adopted_epoch=1)
    _soak_client(spec.to_dict(), 0)
    result = _read_fleet_blob(control, f"{_RESULT_PREFIX}{nid}")
    assert result["adopted"] is True and result["lease_epoch"] == 1

    # reverse order in a fresh store: the adopter overwrites the epoch-0
    # deposit, so adopted=True sticks either way
    spec2 = _spec(tmp_path / "b", num_nodes=1, rounds=2, round_sleep=0.0)
    control2 = control_folder(spec2.store_uri)
    _soak_client(spec2.to_dict(), 0)
    _soak_client(spec2.to_dict(), 0, adopted_epoch=1)
    result2 = _read_fleet_blob(control2, f"{_RESULT_PREFIX}{nid}")
    assert result2["adopted"] is True and result2["lease_epoch"] == 1


def test_late_joiner_adopts_ghost_fleet(tmp_path):
    """Elastic join: a worker arriving AFTER the founding worker died finds
    only expired leases, adopts every slot, and completes the soak alone."""
    from repro.core.fleet import lease_key
    from repro.core.serialize import serialize_fleet_blob

    spec = _spec(tmp_path, num_nodes=3, rounds=3, lease_ttl=0.3,
                 result_timeout=60.0, chaos=ChaosSpec(seed=1, kill_workers=1))
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    now = time.time()
    for slot in range(spec.num_nodes):
        nid = spec.node_id(slot)
        control.put(lease_key(nid, 0), serialize_fleet_blob("lease", {
            "worker": "ghost", "slot": slot, "node_id": nid, "epoch": 0,
            "deadline": now - 60.0, "time": now - 120.0}))
    report = run_worker(spec=spec, control=control, worker_id="rescuer",
                        max_slots=0, timeout=60.0)
    assert sorted(report.adoptions) == [spec.node_id(s) for s in range(3)]
    fleet = assemble_report(control, spec)
    assert fleet.passed, fleet.summary()
    assert fleet.workers_lost == ["ghost"]
    assert fleet.stranded == sorted(spec.node_ids())
    assert all(fleet.adopted[n] for n in fleet.stranded)


def test_fleet_spec_validates_churn_fields(tmp_path):
    with pytest.raises(ValueError):
        _spec(tmp_path, lease_ttl=0.0)
    with pytest.raises(ValueError):
        _spec(tmp_path, chaos=ChaosSpec(kill_workers=-1))
    with pytest.raises(ValueError):
        _spec(tmp_path, rounds=1, chaos=ChaosSpec(kill_workers=1))
    spec = _spec(tmp_path, lease_ttl=2.5,
                 chaos=ChaosSpec(kill_workers=1, kill_workers_after=(2, 4)))
    clone = FleetSpec.from_dict(spec.to_dict())
    assert clone.lease_ttl == 2.5
    assert clone.chaos.kill_workers == 1
    assert clone.chaos.kill_workers_after == (2, 4)


# --- backstop timer vs clean finish (regression) -----------------------------


@pytest.mark.multiprocess
def test_backstop_disarmed_when_victim_finishes_cleanly(tmp_path):
    """Regression: a kill victim whose node finishes cleanly (here: resuming
    a store already past its rounds, so it deposits a result immediately)
    must NOT be SIGKILLed by the armed backstop, counted as a crash, or
    restarted."""
    clean = _spec(tmp_path, runner="process", num_nodes=2, rounds=3,
                  round_sleep=0.05, settle=0.3, result_timeout=60.0)
    report = run_fleet_local(clean, num_workers=1, timeout=120.0)
    assert report.passed, report.summary()
    control = control_folder(clean.store_uri)
    for key in list(control.keys()):  # clear the control plane, keep latest/
        if key.startswith("fleet/"):
            control.delete(key)
    # kill_grace must comfortably exceed process spawn + import time on a
    # loaded machine: the victim only "finishes cleanly" if it gets to run
    # before the backstop fires (1.0s flaked under full-suite load)
    chaotic = _spec(tmp_path, runner="process", num_nodes=2, rounds=3,
                    round_sleep=0.05, settle=0.3, result_timeout=60.0,
                    chaos=ChaosSpec(seed=2, kills=1, kill_grace=5.0,
                                    restart_after=0.1))
    write_spec(control, chaotic)
    report = run_worker(spec=chaotic, control=control, worker_id="rerun",
                        timeout=120.0)
    # every node resumed past its rounds and finished instantly — the armed
    # backstop must have been cancelled, not fired
    assert report.crashes_injected == 0
    assert report.restarts == 0
    fleet = assemble_report(control, chaotic)
    assert fleet.complete and fleet.crashes_injected == 0
