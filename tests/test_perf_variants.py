"""§Perf optimization variants must be EXACTLY interchangeable with their
baselines (the hillclimbs trade roofline terms, never semantics):

  H1 gather MoE dispatch  == einsum dispatch   (fwd + grads)
  H2 absorbed MLA         == naive MLA         (fwd + grads)
  H3 dots remat policy    == full remat        (loss + grads)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import moe as moe_mod


@pytest.mark.parametrize("arch", ["grok-1-314b", "llama4-scout-17b-a16e"])
def test_gather_dispatch_matches_einsum(arch):
    cfg = get_config(arch).reduced().replace(dtype="float32")
    rng = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 96, cfg.d_model)) * 0.5
    out_e, aux_e = moe_mod.moe_apply(p, cfg, x)
    out_g, aux_g = moe_mod.moe_apply_gather(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g), rtol=1e-5, atol=1e-5)
    assert float(abs(aux_e - aux_g)) < 1e-6
    g_e = jax.grad(lambda q: moe_mod.moe_apply(q, cfg, x)[0].sum())(p)
    g_g = jax.grad(lambda q: moe_mod.moe_apply_gather(q, cfg, x)[0].sum())(p)
    for a, b in zip(jax.tree.leaves(g_e), jax.tree.leaves(g_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_absorbed_mla_matches_naive_full_path():
    cfg = get_config("minicpm3-4b").reduced()
    m_naive = build_model(cfg)
    m_abs = build_model(cfg.replace(mla_absorb=True))
    rng = jax.random.PRNGKey(1)
    params = m_naive.init(rng)
    # short (dense sdpa) and long (chunked) sequence paths
    for S in (48, 2048):
        tokens = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)
        l1, _ = m_naive.apply(params, tokens)
        l2, _ = m_abs.apply(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)


def test_remat_policies_agree():
    cfg = get_config("granite-3-2b").reduced()
    rng = jax.random.PRNGKey(2)
    batch = {
        "tokens": jax.random.randint(rng, (2, 1536), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (2, 1536), 0, cfg.vocab_size),
    }
    losses, grads = {}, {}
    for policy in ("full", "dots"):
        model = build_model(cfg.replace(remat_policy=policy))
        params = model.init(jax.random.PRNGKey(3))
        (losses[policy], _), grads[policy] = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=True), has_aux=True
        )(params)
    assert float(abs(losses["full"] - losses["dots"])) < 1e-5
    for a, b in zip(jax.tree.leaves(grads["full"]), jax.tree.leaves(grads["dots"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_probs_bf16_close_enough():
    """bf16 P·V is an approximation — bounded, not exact."""
    from repro.models import attention as A

    rng = jax.random.PRNGKey(4)
    k1, k2, k3 = jax.random.split(rng, 3)
    B, S, KV, G, hd = 1, 2048, 2, 2, 64
    q = jax.random.normal(k1, (B, S, KV, G, hd), jnp.bfloat16)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.bfloat16)
    ref = A.chunked_sdpa(q, k, v, causal=True, probs_bf16=False)
    fast = A.chunked_sdpa(q, k, v, causal=True, probs_bf16=True)
    err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - fast.astype(jnp.float32))))
    assert err < 5e-2, err
