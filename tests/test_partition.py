import numpy as np
from _hyp import given, settings, strategies as st

from repro.core.partition import (
    label_partitions,
    partition_dataset,
    partition_sequence_dataset,
    skewed_assignment,
)


@settings(max_examples=30, deadline=None)
@given(
    num_nodes=st.integers(2, 6),
    skew=st.floats(0.0, 1.0),
    seed=st.integers(0, 100),
)
def test_partition_is_exact_cover(num_nodes, skew, seed):
    """Every example lands on exactly one node, for any skew (hypothesis)."""
    labels = np.repeat(np.arange(10), 20)
    x = np.arange(len(labels))[:, None]
    shards = partition_dataset(x, labels, num_nodes, skew, seed=seed)
    all_ids = np.concatenate([s[0][:, 0] for s in shards])
    assert len(all_ids) == len(labels)
    assert set(all_ids.tolist()) == set(range(len(labels)))


def test_full_skew_is_pure():
    labels = np.repeat(np.arange(10), 100)
    assign = skewed_assignment(labels, 2, 1.0, seed=0)
    assert set(assign[labels < 5]) == {0}
    assert set(assign[labels >= 5]) == {1}


def test_zero_skew_is_roughly_uniform():
    labels = np.repeat(np.arange(10), 500)
    assign = skewed_assignment(labels, 5, 0.0, seed=0)
    counts = np.bincount(assign, minlength=5)
    assert counts.min() > 0.8 * len(labels) / 5


def test_partial_skew_majority():
    """skew=0.9 → ~90%+10%/n of a node's own labels come from its partition."""
    labels = np.repeat(np.arange(10), 1000)
    assign = skewed_assignment(labels, 2, 0.9, seed=1)
    own = assign[labels < 5] == 0
    assert 0.92 < own.mean() < 0.98  # 0.9 + 0.1/2 = 0.95 expected


def test_label_partitions_contiguous():
    owners = label_partitions(np.arange(10), 2, 10)
    assert owners.tolist() == [0] * 5 + [1] * 5


def test_sequence_partition_covers_stream():
    stream = np.arange(1000)
    shards = partition_sequence_dataset(stream, 3)
    assert sum(len(s) for s in shards) == 1000
    assert np.array_equal(np.concatenate(shards), stream)
