"""Launch-layer units: sharding rules/resolver, input specs, cost model,
collective-bytes parser. (The full 512-device dry-run runs via
repro.launch.dryrun, not pytest — no XLA_FLAGS here.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import costs as C
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_spec, resolve
from repro.launch.specs import SHAPES, cache_specs, decode_window_override, input_specs, params_specs


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_param_spec_rules():
    assert param_spec("blocks/u0_attn/attn/wq/w", 3, fsdp=False, dp="data") == P(None, None, "model")
    assert param_spec("blocks/u0_attn/attn/wo/w", 3, fsdp=False, dp="data") == P(None, "model", None)
    assert param_spec("blocks/u0_attn/attn/wo/w", 3, fsdp=True, dp="data") == P(None, "model", "data")
    assert param_spec("embed/table", 2, fsdp=False, dp="data") == P("model", None)
    assert param_spec("blocks/u0_moe_attn/moe/wi/w", 4, fsdp=True, dp="data") == P(None, None, "data", "model")
    assert param_spec("blocks/u0_moe_attn/moe/router/w", 3, fsdp=True, dp="data") == P()
    assert param_spec("final_norm/scale", 1, fsdp=True, dp="data") == P()
    # optimizer moments embed the param path → same rule applies
    assert param_spec("m/blocks/u0_attn/attn/wq/w", 3, fsdp=False, dp="data") == P(None, None, "model")


def test_resolver_drops_non_divisible():
    mesh = make_host_mesh(model_axis=1)  # (1 device) — degenerate but exercises logic
    s = resolve(P("data", "model"), (3, 5), mesh)
    # 3 % 1 == 0 → kept ("data" of size 1); same for model
    assert s.spec == P("data", "model")


def test_resolver_fallback_tuple_axis():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = resolve(P(("pod", "data"),), (7,), jax.make_mesh((1, 1, 1), ("pod", "data", "model")))
    assert s.spec[0] in (("pod", "data"), "data", None)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_token_budget(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    if shape.kind == "decode":
        assert specs["token"].shape == (shape.global_batch,)
        return
    total = specs["tokens"].shape[1]
    if cfg.is_encdec:
        total += specs["frames"].shape[1]
    elif "embeds" in specs:
        total += specs["embeds"].shape[1]
    assert total == shape.seq_len
    assert specs["tokens"].shape[0] == shape.global_batch


def test_cache_specs_long_context_window():
    cfg = get_config("qwen3-14b")
    shape = SHAPES["long_500k"]
    assert decode_window_override(cfg, shape) == cfg.long_context_window
    cache = cache_specs(cfg, shape)
    k = cache["u0_attn"]["k"]
    assert k.shape[2] == cfg.long_context_window  # ring capacity = window, not 500k


def test_cache_specs_ssm_state_only():
    cfg = get_config("mamba2-130m")
    cache = cache_specs(cfg, SHAPES["long_500k"])
    assert set(cache["u0_ssm"].keys()) == {"conv", "ssm"}


def test_params_specs_no_allocation():
    cfg = get_config("grok-1-314b")
    shapes = params_specs(cfg)  # eval_shape: would OOM instantly if real
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    assert 250e9 < n < 400e9  # ~314B params


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_jaxpr_costs_counts_matmul_exactly():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    costs = C.jaxpr_costs(f, a, b)
    assert costs.flops == 2 * 64 * 128 * 32


def test_jaxpr_costs_multiplies_scan_trips():
    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 32, 32), jnp.float32)
    costs = C.jaxpr_costs(scanned, x, w)
    assert costs.flops == 10 * 2 * 32**3  # trip-count aware (XLA reports 1/10th)


def test_jaxpr_costs_sees_through_remat_and_grad():
    def f(w, x):
        body = jax.checkpoint(lambda h, wi: (jnp.tanh(h @ wi), None))
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)

    w = jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    fwd = C.jaxpr_costs(f, w, x).flops
    bwd = C.jaxpr_costs(lambda w, x: jax.grad(f)(w, x), w, x).flops
    assert fwd == 4 * 2 * 16**3
    assert bwd >= 2.5 * fwd  # fwd + remat recompute + 2-matmul backward


def test_collective_bytes_parser():
    hlo = """
body.1 (arg: f32[8]) -> f32[8] {
  %x = f32[1024,256]{1,0} all-reduce(%y), replica_groups=[]
}

ENTRY %main () -> f32[8] {
  %z = bf16[512]{0} all-gather(%w), channel_id=1
  %t = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b)
}
"""
    out = C.collective_bytes(hlo, loop_trip_count=10.0)
    assert out["all-reduce"] == 1024 * 256 * 4 * 10  # loop body × trips
    assert out["all-gather"] == 512 * 2               # ENTRY × 1
    assert out["all-to-all"] == 2 * 16 * 16 * 4
    assert out["total"] == out["all-reduce"] + out["all-gather"] + out["all-to-all"]


def test_roofline_terms_bottleneck():
    t = C.roofline_terms(total_flops=1e15, total_bytes=1e12, coll_bytes=1e10, chips=256)
    assert t["bottleneck"] == "compute_s"
    t2 = C.roofline_terms(total_flops=1e12, total_bytes=1e14, coll_bytes=0.0, chips=256)
    assert t2["bottleneck"] == "memory_s"
