import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.serialize import NodeUpdate
from repro.core.strategies import (
    STRATEGIES,
    FedAdam,
    FedAsync,
    FedAvg,
    FedAvgM,
    FedBuff,
    PartialFedAvg,
    get_strategy,
)
from repro.core.tree import tree_allclose


def upd(val, n=10, node="x", counter=0):
    params = {"layer": {"w": np.full((3, 2), float(val), np.float32)},
              "head": np.full((4,), float(val) * 2, np.float32)}
    return NodeUpdate(params, num_examples=n, node_id=node, counter=counter)


def test_fedavg_weighted():
    out = FedAvg().aggregate(upd(0.0, n=100), [upd(4.0, n=300, node="y")])
    assert np.allclose(out["layer"]["w"], 3.0)


@settings(max_examples=25, deadline=None)
@given(vals=st.lists(st.floats(-5, 5), min_size=1, max_size=5),
       ns=st.lists(st.integers(1, 1000), min_size=5, max_size=5))
def test_fedavg_bounds(vals, ns):
    """FedAvg output within [min,max] of inputs for any example counts."""
    own = upd(vals[0], n=ns[0])
    peers = [upd(v, n=ns[i + 1], node=f"p{i}") for i, v in enumerate(vals[1:])]
    out = FedAvg().aggregate(own, peers)
    assert out["layer"]["w"].min() >= min(vals) - 1e-5
    assert out["layer"]["w"].max() <= max(vals) + 1e-5


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_all_strategies_identity_on_identical(name):
    """Any strategy aggregating identical params must return those params
    (FedBuff returns own params before its buffer fills — same thing)."""
    kwargs = {"buffer_size": 2} if name == "fedbuff" else {}
    strat = get_strategy(name, **kwargs)
    own = upd(1.5)
    peers = [upd(1.5, node="p0"), upd(1.5, node="p1")]
    out = strat.aggregate(own, peers)
    assert tree_allclose(out, own.params, rtol=1e-4, atol=1e-4), name


def test_fedavgm_momentum_accumulates():
    strat = FedAvgM(server_lr=1.0, momentum=0.9)
    own = upd(1.0)
    out1 = strat.aggregate(own, [upd(0.0, node="p")])
    # x=1, avg=0.5 → delta=0.5 → buf=0.5 → x=0.5
    assert np.allclose(out1["layer"]["w"], 0.5)
    out2 = strat.aggregate(upd(0.5), [upd(0.5, node="p")])
    # avg=0.5, delta=0 → buf=0.45 → x=0.05: momentum keeps moving
    assert np.allclose(out2["layer"]["w"], 0.05, atol=1e-6)


def test_fedadam_moves_toward_average():
    strat = FedAdam(server_lr=0.1)
    out = strat.aggregate(upd(1.0), [upd(0.0, node="p")])
    assert np.all(out["layer"]["w"] < 1.0)


def test_fedasync_staleness_discounts():
    fresh = FedAsync(alpha=0.5, staleness_fn="poly", a=1.0)
    own = upd(0.0, counter=10)
    out_fresh = fresh.aggregate(own, [upd(1.0, node="p", counter=10)])
    out_stale = fresh.aggregate(own, [upd(1.0, node="p", counter=0)])
    # stale peer (staleness 10) must move us less than a fresh peer
    assert out_stale["layer"]["w"][0, 0] < out_fresh["layer"]["w"][0, 0]
    assert np.allclose(out_fresh["layer"]["w"], 0.5)  # α·s(0)=0.5 mix


def test_fedbuff_waits_for_buffer():
    strat = FedBuff(buffer_size=3)
    own = upd(0.0)
    out1 = strat.aggregate(own, [])
    assert tree_allclose(out1, own.params)  # buffer has 1 < 3 → own params
    out2 = strat.aggregate(own, [upd(3.0, node="p0"), upd(6.0, node="p1")])
    assert np.allclose(out2["layer"]["w"], 3.0)  # buffer full → mean


def test_fedbuff_dedups_by_counter():
    strat = FedBuff(buffer_size=3)
    own = upd(0.0)
    stale_peer = upd(9.0, node="p0", counter=0)
    strat.aggregate(own, [stale_peer])
    out = strat.aggregate(own, [stale_peer])  # same counter → not re-buffered
    assert tree_allclose(out, own.params)


def test_partial_fedavg_only_shares_matching():
    strat = PartialFedAvg(shared_pattern=r"^layer/")
    out = strat.aggregate(upd(0.0), [upd(2.0, node="p")])
    assert np.allclose(out["layer"]["w"], 1.0)   # federated
    assert np.allclose(out["head"], 0.0)         # personal, untouched


def test_kernel_backed_fedavg_matches():
    plain = FedAvg().aggregate(upd(1.0, n=10), [upd(5.0, n=30, node="p")])
    kern = FedAvg(use_kernel=True).aggregate(upd(1.0, n=10), [upd(5.0, n=30, node="p")])
    assert tree_allclose(plain, kern, rtol=1e-5, atol=1e-5)


# --- async strategy semantics (FedAsync staleness, FedBuff buffering) --------


@pytest.mark.parametrize("fn", ["poly", "hinge", "const"])
def test_fedasync_discount_monotone_nonincreasing(fn):
    """s(staleness) must never grow with staleness, for every discount family."""
    strat = FedAsync(staleness_fn=fn, a=0.5, b=4)
    discounts = [strat._discount(s) for s in range(0, 20)]
    assert all(d1 >= d2 - 1e-12 for d1, d2 in zip(discounts, discounts[1:])), discounts
    assert all(0.0 < d <= 1.0 for d in discounts)


def test_fedasync_poly_strictly_decreasing_const_flat():
    poly = FedAsync(staleness_fn="poly", a=0.5)
    assert poly._discount(0) > poly._discount(1) > poly._discount(5)
    const = FedAsync(staleness_fn="const")
    assert const._discount(0) == const._discount(100) == 1.0


def test_fedasync_hinge_flat_then_decaying():
    hinge = FedAsync(staleness_fn="hinge", a=0.5, b=4)
    assert hinge._discount(0) == hinge._discount(4) == 1.0
    assert hinge._discount(5) < 1.0
    assert hinge._discount(10) < hinge._discount(5)


def test_fedasync_mixing_bounded_by_alpha():
    """Aggregate must stay within [own, own + α·(peer − own)] per peer."""
    strat = FedAsync(alpha=0.3, staleness_fn="const")
    out = strat.aggregate(upd(0.0), [upd(10.0, node="p")])
    assert np.allclose(out["layer"]["w"], 3.0)  # α · s(0) = 0.3 of the gap


def test_fedbuff_rebuffers_newer_counter():
    """A peer's *newer* update re-enters the buffer after a flush; replays of
    the same counter do not."""
    strat = FedBuff(buffer_size=2)
    own = upd(0.0)
    out = strat.aggregate(own, [upd(4.0, node="p", counter=0)])
    assert np.allclose(out["layer"]["w"], 2.0)  # flushed at threshold
    # replay of counter 0 → ignored, buffer only has own → own params back
    out = strat.aggregate(own, [upd(4.0, node="p", counter=0)])
    assert tree_allclose(out, own.params)
    # the peer progressed to counter 1 → buffered again → flush
    out = strat.aggregate(own, [upd(8.0, node="p", counter=1)])
    assert np.allclose(out["layer"]["w"], 4.0)


def test_fedbuff_counts_distinct_nodes_not_updates():
    strat = FedBuff(buffer_size=3)
    own = upd(0.0)
    # two successive updates from the same peer must not fill a 3-buffer
    strat.aggregate(own, [upd(1.0, node="p", counter=0)])
    out = strat.aggregate(own, [upd(2.0, node="p", counter=1)])
    assert tree_allclose(out, own.params)  # still only {own, p} buffered
    out = strat.aggregate(own, [upd(3.0, node="q", counter=0)])
    assert not tree_allclose(out, own.params)  # third distinct node → flush


# --- FedAsync epoch-gap discount (elastic-fleet churn) -----------------------


def eupd(val, *, node="x", counter=0, lease_epoch=0, n=10):
    u = upd(val, n=n, node=node, counter=counter)
    u.lease_epoch = lease_epoch
    return u


def test_fedasync_epoch_gap_damps_adopted_peers():
    """A peer running at a higher lease epoch (adopted after worker death)
    mixes in with weight α·(1+gap)^(-epoch_a); const staleness isolates the
    epoch term."""
    strat = FedAsync(alpha=0.4, staleness_fn="const", epoch_a=1.0)
    base = strat.aggregate(eupd(0.0), [eupd(10.0, node="p")])
    assert np.allclose(base["layer"]["w"], 4.0)  # α alone
    damped = strat.aggregate(eupd(0.0), [eupd(10.0, node="p", lease_epoch=1)])
    assert np.allclose(damped["layer"]["w"], 2.0)  # α/(1+1)
    more = strat.aggregate(eupd(0.0), [eupd(10.0, node="p", lease_epoch=3)])
    assert np.allclose(more["layer"]["w"], 1.0)  # α/(1+3)


def test_fedasync_epoch_gap_is_one_sided():
    """Only peers AHEAD in epochs are damped: the adopted node itself (own
    epoch high, peers at 0) absorbs the live consensus at full strength."""
    strat = FedAsync(alpha=0.4, staleness_fn="const", epoch_a=1.0)
    own = eupd(0.0, lease_epoch=2)
    out = strat.aggregate(own, [eupd(10.0, node="p", lease_epoch=0)])
    assert np.allclose(out["layer"]["w"], 4.0)  # no damping


def test_fedasync_epoch_gap_disabled_and_backcompat():
    """epoch_a=0 disables the term; gap-0 updates aggregate bit-identically
    to a strategy that predates lease epochs."""
    off = FedAsync(alpha=0.4, staleness_fn="const", epoch_a=0.0)
    out = off.aggregate(eupd(0.0), [eupd(10.0, node="p", lease_epoch=5)])
    assert np.allclose(out["layer"]["w"], 4.0)
    legacy = FedAsync(alpha=0.4, staleness_fn="const")
    a = legacy.aggregate(upd(0.0), [upd(10.0, node="p")])
    b = legacy.aggregate(eupd(0.0), [eupd(10.0, node="p", lease_epoch=0)])
    assert np.array_equal(a["layer"]["w"], b["layer"]["w"])


def test_fedasync_epoch_gap_composes_with_staleness():
    strat = FedAsync(alpha=0.8, staleness_fn="poly", a=1.0, epoch_a=1.0)
    own = eupd(0.0, counter=3)
    peer = eupd(10.0, node="p", counter=1, lease_epoch=1)
    out = strat.aggregate(own, [peer])
    # α · (1+staleness=2)^(-1) · (1+gap=1)^(-1) = 0.8/3/2
    assert np.allclose(out["layer"]["w"], 10.0 * 0.8 / 6.0, rtol=1e-5)
