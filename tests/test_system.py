"""End-to-end behaviour tests for the serverless federated system.

These exercise the whole stack (data → skew partition → trainer → callback →
node → store → strategy) on the paper's MNIST-CNN setup at reduced scale and
assert the paper's *qualitative* claims:

  1. under full label skew, a federated node classifies labels it has never
     seen (the defining effect of federation);
  2. synchronous serverless federation leaves all nodes with identical params;
  3. a crashed peer halts synchronous training but not asynchronous training.
"""
import numpy as np
import pytest

import jax

from repro.core import (
    AsyncFederatedNode,
    FederatedCallback,
    FederationTimeout,
    InMemoryFolder,
    SyncFederatedNode,
    run_threaded,
)
from repro.core.partition import partition_dataset
from repro.core.strategies import FedAvg
from repro.data import batch_iterator, make_synthetic_mnist
from repro.models.cnn import MnistCNN
from repro.optim import adam
from repro.training import Trainer

NUM_NODES = 2
EPOCHS = 3
STEPS = 25
BATCH = 32


@pytest.fixture(scope="module")
def dataset():
    return make_synthetic_mnist(num_train=3000, num_test=600, seed=0)


def make_trainer(shard, seed, name, slowdown=0.0):
    model = MnistCNN()
    # FedAvg requires a COMMON initialization across clients (McMahan et al.);
    # the per-node seed only drives data order.
    params = model.init(jax.random.PRNGKey(0))
    trainer = Trainer(
        loss_fn=lambda p, b, r: model.loss(p, b),
        optimizer=adam(1e-3),
        init_params=params,
        seed=seed,
        name=name,
        slowdown=slowdown,
    )
    x, y = shard
    data_fn = lambda epoch: batch_iterator(x, y, batch_size=BATCH, seed=seed, epoch=epoch)
    return trainer, data_fn


def evaluate(params, dataset):
    model = MnistCNN()
    logits = model.apply(params, dataset.x_test)
    return float((np.argmax(np.asarray(logits), -1) == dataset.y_test).mean())


def run_async_federation(dataset, skew, federate=True, epochs=10, steps=15):
    """Deterministic round-robin schedule over real AsyncFederatedNodes:
    each node runs one local epoch then federates via the shared store, in
    turn. Same node logic as the threaded runs (which test_crash/* cover),
    but reproducible — the accuracy assertion must not hinge on the GIL."""
    shards = partition_dataset(dataset.x_train, dataset.y_train, NUM_NODES, skew, seed=0)
    folder = InMemoryFolder()
    trainers, nodes = [], []
    for i in range(NUM_NODES):
        trainer, data_fn = make_trainer(shards[i], seed=i, name=f"n{i}")
        trainers.append((trainer, data_fn))
        nodes.append(AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id=f"n{i}"))
    for epoch in range(epochs):
        for i, (trainer, data_fn) in enumerate(trainers):
            trainer.run_epoch(data_fn(epoch), steps)
            if federate:
                new = nodes[i].update_parameters(trainer.host_params(),
                                                 num_examples=steps * BATCH)
                if new is not None:
                    trainer.set_params(new)
    return [evaluate(t.host_params(), dataset) for t, _ in trainers]


def test_async_federation_learns_unseen_labels(dataset):
    """Full skew: node 0 sees only digits 0-4. Without federation it cannot
    exceed ~62% on the full test set; with federation it must do better."""
    solo = run_async_federation(dataset, skew=1.0, federate=False)
    fed = run_async_federation(dataset, skew=1.0, federate=True)
    assert max(solo) < 0.62, f"solo unexpectedly high: {solo}"
    assert max(fed) > max(solo) + 0.10, f"federation did not help: fed={fed} solo={solo}"


def test_sync_federation_all_nodes_identical(dataset):
    shards = partition_dataset(dataset.x_train, dataset.y_train, NUM_NODES, 0.5, seed=0)
    folder = InMemoryFolder()
    finals = {}

    def client(i):
        trainer, data_fn = make_trainer(shards[i], seed=i, name=f"s{i}")
        node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id=f"s{i}",
                                 num_nodes=NUM_NODES, timeout=120)
        cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
        trainer.fit(data_fn, epochs=2, steps_per_epoch=STEPS, callbacks=[cb])
        finals[i] = trainer.host_params()

    results = run_threaded([lambda i=i: client(i) for i in range(NUM_NODES)])
    assert all(r.error is None for r in results), [r.traceback for r in results]
    w0 = jax.tree.leaves(finals[0])
    w1 = jax.tree.leaves(finals[1])
    for a, b in zip(w0, w1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_crash_halts_sync_but_not_async(dataset):
    shards = partition_dataset(dataset.x_train, dataset.y_train, 2, 0.0, seed=0)
    # --- async: survivor completes all epochs despite peer crash at epoch 1
    folder = InMemoryFolder()

    def async_crasher():
        trainer, data_fn = make_trainer(shards[0], seed=0, name="crash")
        node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="crash")
        cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
        trainer.fit(data_fn, epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[cb],
                    crash_at_epoch=1)

    def async_survivor():
        trainer, data_fn = make_trainer(shards[1], seed=1, name="ok")
        node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id="ok")
        cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
        trainer.fit(data_fn, epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[cb])
        return len(trainer.log)

    res = run_threaded([async_crasher, async_survivor])
    assert res[0].error is not None          # the crash happened
    assert res[1].error is None and res[1].result == EPOCHS  # survivor unaffected

    # --- sync: the same crash deadlocks the healthy node (bounded by timeout)
    folder2 = InMemoryFolder()

    def sync_crasher():
        trainer, data_fn = make_trainer(shards[0], seed=0, name="crash2")
        node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder2, node_id="crash2",
                                 num_nodes=2, timeout=30)
        cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
        trainer.fit(data_fn, epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[cb],
                    crash_at_epoch=1)

    def sync_victim():
        trainer, data_fn = make_trainer(shards[1], seed=1, name="victim")
        node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder2, node_id="victim",
                                 num_nodes=2, timeout=3.0)
        cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
        trainer.fit(data_fn, epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[cb])

    res2 = run_threaded([sync_crasher, sync_victim])
    assert res2[0].error is not None
    assert isinstance(res2[1].error, FederationTimeout)  # sync cannot proceed
