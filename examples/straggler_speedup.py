"""Straggler & failure robustness demo — the paper's core operational claims.

Part 1 (exact, virtual clock): wall-clock of sync vs async federation as one
node gets progressively slower, and under a mid-training node crash.

Part 2 (real threads): two MNIST-CNN clients, one slowed 3×; measures actual
wall time of sync (barrier) vs async (no waiting) federation.

    PYTHONPATH=src python examples/straggler_speedup.py
"""
import time

import jax
import numpy as np

from repro.core import (
    AsyncFederatedNode,
    FederatedCallback,
    InMemoryFolder,
    SyncFederatedNode,
    run_threaded,
    simulate_timeline,
    straggler_speedup,
)
from repro.core.partition import partition_dataset
from repro.core.strategies import FedAvg
from repro.data import batch_iterator, make_synthetic_mnist
from repro.models.cnn import MnistCNN
from repro.optim import adam
from repro.training import Trainer

print("== virtual-clock model (exact) ==")
rng = np.random.default_rng(0)
for ratio in (1.0, 1.5, 2.0, 4.0, 8.0):
    durations = [[1.0 + 0.2 * rng.random() for _ in range(12)],
                 [ratio * (1.0 + 0.2 * rng.random()) for _ in range(12)]]
    print(f"  straggler ×{ratio:>3}: async is {straggler_speedup(durations):.2f}× faster than sync")

tl_sync = simulate_timeline([[1.0] * 6] * 3, mode="sync", failures={2: 3})
tl_async = simulate_timeline([[1.0] * 6] * 3, mode="async", failures={2: 3})
print(f"  node crash at epoch 3: sync wall={tl_sync.wall_clock} (hung), "
      f"async wall={tl_async.wall_clock} (survivors finish)")

print("== real threads (MNIST CNN, node1 slowed 3×) ==")
data = make_synthetic_mnist(num_train=1500, num_test=300)
shards = partition_dataset(data.x_train, data.y_train, 2, 0.5)


def run(mode):
    folder = InMemoryFolder()

    def client(i):
        model = MnistCNN()
        trainer = Trainer(loss_fn=lambda p, b, r: model.loss(p, b), optimizer=adam(1e-3),
                          init_params=model.init(jax.random.PRNGKey(0)), seed=i,
                          name=f"{mode}{i}", slowdown=0.0 if i == 0 else 0.03)
        if mode == "sync":
            node = SyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                                     node_id=f"n{i}", num_nodes=2, timeout=300)
        else:
            node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder, node_id=f"n{i}")
        cb = FederatedCallback(node, num_examples_per_epoch=15 * 32)
        x, y = shards[i]
        trainer.fit(lambda e: batch_iterator(x, y, batch_size=32, seed=i, epoch=e),
                    epochs=3, steps_per_epoch=15, callbacks=[cb])
        return trainer

    t0 = time.time()
    res = run_threaded([lambda i=i: client(i) for i in range(2)])
    assert all(r.error is None for r in res)
    return time.time() - t0


sync_t = run("sync")
async_t = run("async")
print(f"  sync wall: {sync_t:.1f}s   async wall: {async_t:.1f}s   "
      f"→ async {sync_t / async_t:.2f}× faster")
