"""Elastic fleet membership — workers leave (crash) and join mid-soak.

Fleet workers don't own their slots forever: each claim is a **lease**
(``fleet/lease/<node>/<epoch>`` blob carrying the worker id and a
heartbeat-refreshed deadline). A worker that goes silent past
``FleetSpec.lease_ttl`` forfeits its slots — any live worker adopts the
lapsed lease with one atomic ``put_if_absent`` at the next epoch and resumes
the node from its own ``latest/`` deposits. Membership is therefore
*elastic*: workers can be SIGKILLed whole, and fresh workers can join a
soak that is already running.

This script demos both directions in one process:

1. two founding workers claim the fleet; ``ChaosSpec(kill_workers=1)``
   deterministically draws one of them and kills it whole mid-soak
   (its nodes stop pushing, its leases go stale);
2. a **late-joining rescuer** worker starts *after* the soak is underway
   with ``max_slots=0`` — it claims nothing, finds the stranded leases,
   adopts them at epoch 1, and finishes the dead worker's nodes.

Run it::

    PYTHONPATH=src python examples/elastic_fleet.py
    PYTHONPATH=src python examples/elastic_fleet.py --nodes 12 --rounds 8

Across real terminals/machines the same flow is the CLI (the rescuer can
start any time, even after the victim is long dead)::

    PYTHONPATH=src python -m repro.fleet init --store /mnt/shared/soak \\
        --nodes 9 --rounds 6 --chaos-kill-workers 1 --lease-ttl 2
    PYTHONPATH=src python -m repro.fleet worker --store /mnt/shared/soak \\
        --worker-id hostA --max-slots 5 &        # one of these self-SIGKILLs
    PYTHONPATH=src python -m repro.fleet worker --store /mnt/shared/soak \\
        --worker-id hostB --max-slots 4 &
    PYTHONPATH=src python -m repro.fleet worker --store /mnt/shared/soak \\
        --worker-id rescuer --max-slots 0        # joins late, adopts strays

The soak passes only if every node finished, the survivors agree on one
fleet-wide ``state_hash``, at least one founding worker was lost, and every
stranded node reports ``adopted=True`` — the acceptance ``repro.fleet
report --assert-passed`` checks, and the bar CI's churn tier holds.
"""
import argparse
import tempfile
import threading
import time

from repro.core import ChaosSpec, FleetSpec, assemble_report, run_worker
from repro.core.fleet import control_folder, read_spec, write_spec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None,
                    help="shared folder URI (default: fresh temp dir)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--lease-ttl", type=float, default=1.0,
                    help="lease freshness window; a worker silent this long "
                         "forfeits its slots to adoption")
    ap.add_argument("--join", action="store_true",
                    help="skip init: act only as a late-joining rescuer "
                         "against a soak already running at --store")
    args = ap.parse_args(argv)

    if args.join:
        if not args.store:
            ap.error("--join needs --store pointing at the running soak")
        report = run_worker(args.store, worker_id="rescuer", max_slots=0)
        print(f"rescuer adopted: {sorted(report.adoptions)}")
        raise SystemExit(0)

    store = args.store or tempfile.mkdtemp(prefix="elastic_fleet_")
    spec = FleetSpec(
        store_uri=store,
        num_nodes=args.nodes,
        rounds=args.rounds,
        runner="thread",
        round_sleep=0.05,
        settle=1.0,
        lease_ttl=args.lease_ttl,
        chaos=ChaosSpec(seed=args.seed, kill_workers=1,
                        kill_workers_after=(1, 3)),
    )
    write_spec(control_folder(store), spec)
    print(f"soaking {spec.num_nodes} nodes x {spec.rounds} rounds over "
          f"{store!r}: 2 founding workers, kill_workers=1, "
          f"lease_ttl={spec.lease_ttl}s")

    # Two founding workers split the fleet. The seeded worker-kill chaos
    # draws one of them; mid-soak it stops dead (threads aborted, leases
    # left to go stale) — exactly what a SIGKILLed host looks like from the
    # store's point of view.
    founders = [
        threading.Thread(
            target=run_worker, args=(store,),
            kwargs=dict(worker_id=f"founder{i}",
                        max_slots=(spec.num_nodes + 1) // 2),
            daemon=True)
        for i in range(2)
    ]
    for t in founders:
        t.start()

    # The rescuer joins while the soak is running. max_slots=0 means it
    # claims no founding slots at all — its only job is the adoption sweep:
    # wait for leases to lapse, CAS each one at epoch+1, resume the node
    # from latest/, and deposit the missing results.
    time.sleep(1.0)
    print("rescuer joining the running soak (max_slots=0, adoption only)...")
    rescue = run_worker(store, worker_id="rescuer", max_slots=0)
    for t in founders:
        t.join(timeout=30.0)

    control = control_folder(store)
    report = assemble_report(control, read_spec(control))
    print()
    print(report.summary())
    if rescue.adoptions:
        print(f"  rescuer adopted: {sorted(rescue.adoptions)}")
    else:
        print("  (the surviving founder won the adoption race this run — "
              "adoption is a CAS, any live worker may win)")
    for node, latency in sorted(report.adoption_latency.items()):
        print(f"  {node}: lease lapsed -> adopted push in {latency:.2f}s")
    raise SystemExit(0 if report.passed else 1)


if __name__ == "__main__":
    main()
