"""Sharded gossip federation — O(group) scans instead of O(fleet).

The fleet is partitioned into node-groups via a ``shard<G>+<uri>`` store URI;
each group owns its own folder, and cross-group information travels as gossip
summaries (one aggregate blob per group, forwarded along a ring). A node's
per-step ``state_hash``/``pull`` touch only its home group's folder, so scan
cost is flat in fleet size at fixed group size.

    PYTHONPATH=src python examples/sharded_federation.py
    PYTHONPATH=src python examples/sharded_federation.py --nodes 24 --groups 6
    PYTHONPATH=src python examples/sharded_federation.py --processes

The default run federates threaded clients over a sharded temp-dir store and
then prints a flat-vs-sharded scan-cost comparison on simulated fleets.
"""
import argparse
import tempfile
import time

import numpy as np

from repro.core import (
    AsyncFederatedNode,
    InMemoryFolder,
    NodeUpdate,
    ShardedFolders,
    ShardedWeightStore,
    WeightStore,
    balanced_groups,
    make_folder,
    run_threaded,
)
from repro.core.gossip import GROUP_PEER_PREFIX
from repro.core.strategies import FedAvg


def threaded_demo(num_nodes: int, num_groups: int, epochs: int) -> None:
    shared_dir = tempfile.mkdtemp(prefix="flwr_serverless_shard_")
    uri = f"shard{num_groups}+{shared_dir}"
    print(f"weight store: {uri}")
    node_ids = [f"client{i}" for i in range(num_nodes)]
    mapping = balanced_groups(node_ids, num_groups)  # explicit: no empty group
    targets = {nid: float(i) for i, nid in enumerate(node_ids)}
    finals = {}

    def client(nid):
        store = ShardedWeightStore(make_folder(uri), group_of=mapping)
        node = AsyncFederatedNode(strategy=FedAvg(), store=store, node_id=nid)
        w = np.zeros((8,), np.float32)
        pseudo_peers = set()
        for _ in range(epochs):
            w = w + 0.3 * (np.float32(targets[nid]) - w)  # local step
            aggregated = node.update_parameters({"w": w}, num_examples=10)
            if aggregated is not None:
                w = aggregated["w"]
            pseudo_peers.update(
                u.node_id for u in store.pull(exclude=nid)
                if u.node_id.startswith(GROUP_PEER_PREFIX)
            )
            time.sleep(0.02)
        finals[nid] = (float(w.mean()), sorted(pseudo_peers))

    results = run_threaded([lambda n=n: client(n) for n in node_ids])
    errors = [r for r in results if r.error is not None]
    assert not errors, [r.traceback for r in errors]
    values = [v for v, _ in finals.values()]
    print(f"{num_nodes} clients in {num_groups} groups, {epochs} epochs:")
    for nid in node_ids[:4]:
        v, peers = finals[nid]
        print(f"  {nid} (group {mapping[nid]}): final={v:.2f} gossip peers={peers}")
    print(f"  ... consensus spread {max(values) - min(values):.2f} "
          f"(targets spanned {max(targets.values()) - min(targets.values()):.1f})")


def scan_cost_demo() -> None:
    """Per-step scan cost (state_hash + pull): flat store vs sharded store."""
    params = {"w": np.zeros((16,), np.float32)}
    group_size = 50
    print("\nper-step scan cost, group size fixed at "
          f"{group_size} (simulated deposits, memory backend):")
    for fleet in (200, 2000):
        num_groups = fleet // group_size
        flat = WeightStore(InMemoryFolder(), decode_cache_entries=fleet)
        sharded = ShardedWeightStore(
            ShardedFolders(num_groups, factory=lambda g: InMemoryFolder()),
            group_of=lambda nid: int(nid[1:]) % num_groups,
        )
        for store in (flat, sharded):
            for i in range(fleet):
                store.push(NodeUpdate(params, num_examples=1, node_id=f"n{i}"))

        def step_cost(store):
            store.state_hash(exclude_node="n0"); store.pull(exclude="n0")  # warm
            t0 = time.time()
            for _ in range(3):
                store.state_hash(exclude_node="n0")
                store.pull(exclude="n0")
            return (time.time() - t0) / 3

        print(f"  fleet {fleet:5d}: flat {step_cost(flat) * 1e3:7.2f} ms   "
              f"sharded({num_groups} groups) {step_cost(sharded) * 1e3:7.2f} ms")


def _proc_client(shared_dir, nid, mapping, num_groups, target, epochs):
    """Module-level so the spawn start method can pickle it by name."""
    store = ShardedWeightStore(f"shard{num_groups}+{shared_dir}", group_of=mapping)
    node = AsyncFederatedNode(strategy=FedAvg(), store=store, node_id=nid)
    w = np.zeros((8,), np.float32)
    peers = set()
    for _ in range(epochs):
        w = w + 0.3 * (np.float32(target) - w)
        aggregated = node.update_parameters({"w": w}, num_examples=10)
        if aggregated is not None:
            w = aggregated["w"]
        peers.update(u.node_id for u in store.pull(exclude=nid))
        time.sleep(0.05)
    return {"final": float(w.mean()), "peers": sorted(peers)}


def process_demo(num_nodes: int, num_groups: int, epochs: int) -> None:
    """The same federation across real OS processes (see
    tests/test_multiprocess.py for the asserted version)."""
    from repro.core import run_multiprocess

    shared_dir = tempfile.mkdtemp(prefix="flwr_serverless_shard_mp_")
    node_ids = [f"n{i}" for i in range(num_nodes)]
    mapping = balanced_groups(node_ids, num_groups)
    clients = [
        (_proc_client, (shared_dir, nid, mapping, num_groups, float(i), epochs))
        for i, nid in enumerate(node_ids)
    ]
    results = run_multiprocess(clients, names=node_ids, join_timeout=300.0)
    for r in results:
        if r.error is None:
            print(f"  {r.node_id}: final={r.result['final']:.2f} "
                  f"peers={r.result['peers']}")
        else:
            print(f"  {r.node_id}: {r.error}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--processes", action="store_true",
                    help="run clients as real OS processes instead of threads")
    args = ap.parse_args(argv)
    if args.processes:
        process_demo(args.nodes, args.groups, args.epochs)
    else:
        threaded_demo(args.nodes, args.groups, args.epochs)
        scan_cost_demo()


if __name__ == "__main__":
    main()
