"""Serving + per-client strategy heterogeneity demo.

1. Serve a (reduced) Mamba2 model with batched greedy decode — the SSM decode
   path whose O(1) state makes long_500k feasible.
2. The serverless design lets EVERY CLIENT RUN A DIFFERENT AGGREGATION
   STRATEGY (a property the paper calls out): one FedAvg node, one
   staleness-aware FedAsync node, one FedAvgM node, all sharing a store.

    PYTHONPATH=src python examples/serve_and_strategies.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import connect
from repro.configs import get_config
from repro.core import AsyncFederatedNode
from repro.core.strategies import FedAsync, FedAvg, FedAvgM
from repro.launch.serve import serve_batch
from repro.models import build_model

print("== batched serving (mamba2, reduced) ==")
cfg = get_config("mamba2-130m").reduced()
model = build_model(cfg)
rng = jax.random.PRNGKey(0)
params = model.init(rng)
prompts = jax.random.randint(rng, (4, 12), 0, cfg.vocab_size, jnp.int32)
out = serve_batch(cfg, params, prompts, new_tokens=12)
print(f"  served batch of {out.shape[0]}, {out.shape[1]} new tokens each")
print(f"  sample continuation: {np.asarray(out)[0].tolist()}")

print("== heterogeneous per-client strategies ==")
# named memory:// URIs share one in-process folder, so each client can open
# its own store through the facade — same shape as a disk/S3 deployment
uri = "memory://strategies-demo"
weights = {"w": np.zeros((4,), np.float32)}
nodes = {
    "avg": AsyncFederatedNode(strategy=FedAvg(), store=connect(uri), node_id="avg"),
    "asy": AsyncFederatedNode(strategy=FedAsync(alpha=0.5), store=connect(uri), node_id="asy"),
    "mom": AsyncFederatedNode(strategy=FedAvgM(momentum=0.5), store=connect(uri), node_id="mom"),
}
vals = {"avg": 0.0, "asy": 3.0, "mom": 6.0}
for round_ in range(3):
    for name, node in nodes.items():
        new = node.update_parameters({"w": np.full((4,), vals[name], np.float32)}, 100)
        if new is not None:
            vals[name] = float(new["w"][0])
    print(f"  round {round_}: " + "  ".join(f"{n}={vals[n]:.3f}" for n in nodes))
print("  (three different aggregation rules, one store, zero servers)")
