"""Serving tier demo: federated weights flow straight into live traffic.

Two async trainer nodes federate a small decoder LM through one store while
a read-only :class:`ServingNode` (``repro.api.serve``) rides the same store:
it deploys the freshest aggregated weights, hot-swaps with zero-downtime
double buffering as new rounds land, and keeps serving batched greedy decode
throughout. No server, no publish step — the store IS the deployment
pipeline.

    PYTHONPATH=src python examples/federated_serving.py          # ~14M params
    PYTHONPATH=src python examples/federated_serving.py --smoke  # <1 min

Prints per-batch throughput plus the serving SLOs (rounds-behind-store
staleness, swap-latency percentiles) and finishes with the fleet dashboard —
the SERVE row is fed purely from ``obs/`` blobs in the store.
"""
import argparse
import threading
import time

import jax
import numpy as np

from repro.api import connect, serve
from repro.configs import get_config
from repro.core import AsyncFederatedNode, FederatedCallback, run_threaded
from repro.core.strategies import FedAvg
from repro.data import lm_batch_iterator, make_synthetic_wikitext
from repro.models import build_model
from repro.obs import render_dashboard
from repro.core.telemetry import collect_obs
from repro.optim import adamw, chain_clip
from repro.training import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true")
ap.add_argument("--new-tokens", type=int, default=16)
args = ap.parse_args()

CFG = get_config("pythia-14m")
if args.smoke:
    CFG = CFG.reduced()
SEQ, BATCH = 64, 8
EPOCHS, STEPS = (2, 8) if args.smoke else (4, 15)

# named memory:// = one in-process folder shared by every connect() below;
# point this at a disk/NFS path or s3:// bucket for a real deployment
URI = "memory://federated-serving-demo"

model = build_model(CFG)
init_params = model.init(jax.random.PRNGKey(0))  # common init
data = make_synthetic_wikitext(vocab_size=CFG.vocab_size, train_tokens=60_000, seed=0)


def trainer(i: int):
    node = AsyncFederatedNode(
        strategy=FedAvg(), store=connect(URI), node_id=f"trainer{i}",
        telemetry=True)
    cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
    t = Trainer(
        loss_fn=lambda p, b, r: model.loss(p, b),
        optimizer=chain_clip(adamw(3e-4), 1.0),
        init_params=init_params, seed=i, name=f"trainer{i}",
    )
    t.fit(lambda e: lm_batch_iterator(data.train_tokens, batch_size=BATCH,
                                      seq_len=SEQ, seed=i, epoch=e),
          epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[cb], verbose=False)
    # short runs end between flush cadences — deposit one final obs snapshot
    payload = node.telemetry.snapshot(node.transport_stats())
    node.store.push_obs(node.node_id, payload["seq"], payload)
    return {"node": f"trainer{i}", "pushes": node.num_pushes,
            "aggregations": node.num_aggregations}


# serving node first: it joins the (still empty) store read-only and waits
node = serve(connect(URI), CFG, poll_interval=0.2, telemetry=True)

results = []
fleet = threading.Thread(
    target=lambda: results.extend(run_threaded(
        [lambda i=i: trainer(i) for i in range(2)])))
fleet.start()

assert node.wait_until_deployed(120.0), "no weights ever reached the store"
print(f"first deploy: {node.stats()['source']}@{node.stats()['counter']}")

rng = np.random.default_rng(0)
served = 0
while fleet.is_alive() or served == 0:
    prompts = rng.integers(0, CFG.vocab_size, (4, 16), dtype=np.int32)
    t0 = time.monotonic()
    out, meta = node.generate(prompts, new_tokens=args.new_tokens)
    dt = time.monotonic() - t0
    served += 1
    print(f"  batch {served}: {out.size / dt:7.1f} tok/s  "
          f"weights={meta['source']}@{meta['counter']}  "
          f"swaps={node.stats()['swaps']}")
fleet.join()

stats = node.stats()
print(f"served {served} batches across {stats['swaps']} hot swaps")
print(f"staleness (rounds behind store): mean={stats['staleness_mean']:.2f} "
      f"max={stats['staleness_max']:.0f}")
print(f"swap latency: p50={stats['swap_ms_p50']:.1f}ms "
      f"p99={stats['swap_ms_p99']:.1f}ms")
assert stats["swaps"] >= 1, "serving node never deployed"
for r in results:
    assert r.error is None, r.traceback
    print(r.result)

node.flush_obs()
node.stop()
print()
render_dashboard(collect_obs(URI))
