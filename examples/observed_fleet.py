"""An observed fleet — the serverless telemetry plane, end to end.

Serverless federation has no coordinator to scrape, so the telemetry rides
the same store as the weights: each node keeps a lightweight flight recorder
(``Telemetry`` — monotonic-clock spans over pull/decode/aggregate/encode/
push/train, staleness distributions, transport byte counters) and
periodically deposits a snapshot as an ``obs/<node>/<seq>`` blob. The blobs
are excluded from every federation ``state_hash`` (like the ``fleet/``
control plane), survive delta-transport GC, and any host that can see the
mount becomes a dashboard::

    PYTHONPATH=src python examples/observed_fleet.py
    PYTHONPATH=src python examples/observed_fleet.py --store /tmp/obs_demo

    # meanwhile, from ANY terminal/host that sees the store (or after):
    PYTHONPATH=src python -m repro.obs watch --store /tmp/obs_demo --once
    PYTHONPATH=src python -m repro.obs trace --store /tmp/obs_demo --out trace.json
    # open trace.json at https://ui.perfetto.dev — every node's round
    # phases on one timeline, wall-clock aligned across nodes.

Three ways to switch telemetry on (default is OFF, and the disabled path is
a shared no-op context manager — nanoseconds per call):

1. per node: ``AsyncFederatedNode(..., telemetry=True)`` or pass a
   configured ``Telemetry(flush_every=5, obs_keep=16)`` instance;
2. fleet-wide: ``repro.fleet`` soak clients always deposit telemetry, and
   ``SoakReport.summary()`` folds the rollups in;
3. environment: ``REPRO_OBS=1`` flips the default for every node in the
   process (handy for scripts you can't edit).

Debug logging is a separate knob: ``REPRO_LOG=debug`` (or
``REPRO_LOG=debug:fleet`` for one subtree) attaches a stderr handler to the
``repro.*`` logger hierarchy, which is silent by default.
"""
import argparse
import functools
import tempfile

import numpy as np

from repro.core import AsyncFederatedNode, Telemetry, make_folder, run_threaded
from repro.core.telemetry import collect_obs, telemetry_rollups
from repro.obs import render_dashboard


def client(node_id: str, store_uri: str, rounds: int, size: int, seed: int):
    rng = np.random.default_rng(seed)
    node = AsyncFederatedNode(
        shared_folder=make_folder(store_uri),
        node_id=node_id,
        transport="delta",
        # flush_every=1: deposit an obs/ snapshot after every round so even
        # short demo runs produce a trace; real soaks use a larger cadence.
        telemetry=Telemetry(enabled=True, flush_every=1),
    )
    params = {"w": rng.standard_normal(size).astype(np.float32)}
    for _ in range(rounds):
        params = {"w": params["w"] + rng.normal(scale=0.01, size=size).astype(np.float32)}
        merged = node.update_parameters(params, num_examples=1)
        if merged is not None:
            params = merged
    return node.counter


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None,
                    help="shared folder URI (default: fresh temp dir); "
                         "cache+/shard<G>+ wrappers compose")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--size", type=int, default=50_000)
    args = ap.parse_args(argv)

    store = args.store or tempfile.mkdtemp(prefix="observed_fleet_")
    print(f"federating {args.nodes} nodes x {args.rounds} rounds over {store!r}\n")
    run_threaded([
        functools.partial(client, f"n{i}", store, args.rounds, args.size, i)
        for i in range(args.nodes)
    ], names=[f"n{i}" for i in range(args.nodes)])

    # The dashboard is just a store reader — same thing `repro.obs watch`
    # renders, assembled from the obs/ blobs alone:
    obs = collect_obs(store)
    render_dashboard(obs)

    rollups = telemetry_rollups(obs)
    fleet = rollups["fleet"]
    print(f"\nfleet rollup: {fleet['nodes_reporting']} nodes, "
          f"{fleet['rounds_total']} rounds, "
          f"staleness mean {fleet['staleness_mean']:.2f}, "
          f"{fleet['bytes_written'] / 1e6:.2f}MB written")
    print(f"\nnext: PYTHONPATH=src python -m repro.obs trace --store {store} "
          "--out trace.json   # then open it at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
