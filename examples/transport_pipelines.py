"""Walkthrough of the composable transport pipeline (the wire layer).

Every deposit a federated node makes flows through a ``TransportPipeline``
built from one spec string — ``"delta(chain=4)|npz"``, ``"topk(adaptive)"``,
``"quantized|zstd"`` — and every wire counter (bytes written/read, chain
depths, residual norms, prefetch activity) lands on that pipeline's stats.

This script pushes the same sparse-local-step schedule through several
pipelines over one shared schedule and prints what each one moved, then
demonstrates the two runtime features: background prefetch and
strategy-state recovery.

    PYTHONPATH=src python examples/transport_pipelines.py
"""
import numpy as np

from repro.core import (
    AsyncFederatedNode,
    InMemoryFolder,
    NodeUpdate,
    WeightStore,
    normalize_transport,
)
from repro.core.serialize import _zstd_module
from repro.core.strategies import FedAvgM


def sparse_steps(n_params=200_000, pushes=12, fraction=0.005, seed=0):
    """A partial-fine-tuning-style schedule: each step perturbs a small
    fraction of entries — the regime delta transports are built for."""
    rng = np.random.default_rng(seed)
    cur = (np.arange(n_params, dtype=np.float32) % 997) * np.float32(1e-3)
    for _ in range(pushes):
        cur = cur.copy()
        idx = rng.integers(0, n_params, size=int(fraction * n_params))
        cur[idx] += rng.normal(size=idx.size).astype(np.float32)
        yield {"w": cur}


def compare_pipelines():
    envelope = "zstd" if _zstd_module() is not None else "npz"
    specs = ["full", "quantized", "delta", f"delta(chain=4)|{envelope}",
             "topk(adaptive)"]
    print(f"pipeline comparison ({envelope} envelope available)\n")
    print(f"{'spec':<22}{'wire MB':>9}{'rebases':>9}{'re-anchors':>11}"
          f"{'max depth':>11}")
    for spec in specs:
        folder = InMemoryFolder()
        writer = WeightStore(folder, transport=spec)
        reader = WeightStore(folder)
        for ctr, params in enumerate(sparse_steps()):
            writer.push(NodeUpdate(params, num_examples=1, node_id="n",
                                   counter=ctr))
            reader.pull_node("n")
        s = writer.transport_stats()
        wire = (s["bytes_written"] + reader.bytes_read) / 1e6
        print(f"{writer.transport:<22}{wire:>9.2f}{s['rebases']:>9}"
              f"{s['reanchors']:>11}{s['max_chain_depth']:>11}")
    print("\nlegacy names map onto the same grammar:",
          f"delta_q -> {normalize_transport('delta_q')},",
          f"topk|delta -> {normalize_transport('topk|delta')}")


def prefetch_demo():
    print("\nbackground prefetch")
    folder = InMemoryFolder()
    writer = WeightStore(folder)
    reader = WeightStore(folder)
    for i in range(5):
        writer.push(NodeUpdate({"w": np.full((4096,), float(i), np.float32)},
                               num_examples=1, node_id=f"peer{i}", counter=0))
    reader.warm_cache()          # what the prefetch thread runs periodically
    reader.pull()                # the federation step itself: all cache hits
    s = reader.transport_stats()
    print(f"  warmed {s['prefetched']} peers ahead of time; "
          f"the pull paid {s['decode_hits']} cache hits, "
          f"{s['decode_misses'] - s['prefetched']} fresh decodes")


def recovery_demo():
    print("\nstrategy-state recovery (FedAvgM momentum survives a restart)")
    folder = InMemoryFolder()
    a = AsyncFederatedNode(strategy=FedAvgM(), shared_folder=folder,
                           node_id="a", persist_strategy_state=True)
    b = AsyncFederatedNode(strategy=FedAvgM(), shared_folder=folder,
                           node_id="b", persist_strategy_state=True)
    rng = np.random.default_rng(0)
    pa = {"w": rng.normal(size=(512,)).astype(np.float32)}
    pb = {"w": rng.normal(size=(512,)).astype(np.float32)}
    a.update_parameters(pa, num_examples=1)
    b.update_parameters(pb, num_examples=1)
    a.update_parameters(pa, num_examples=1)         # aggregates + persists
    momentum = float(np.abs(a.strategy.buf).sum())
    a2 = AsyncFederatedNode(strategy=FedAvgM(), shared_folder=folder,
                            node_id="a", persist_strategy_state=True)
    restored = float(np.abs(a2.strategy.buf).sum()) if a2.strategy.buf is not None else 0.0
    print(f"  |momentum| before crash = {momentum:.4f}, "
          f"after restart = {restored:.4f} "
          f"({'restored' if restored == momentum else 'LOST'})")


if __name__ == "__main__":
    compare_pipelines()
    prefetch_demo()
    recovery_demo()
