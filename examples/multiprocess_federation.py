"""Multi-process serverless federation — the paper's claim with real processes.

K clients run as separate OS processes (``spawn``: each gets a clean
interpreter) whose ONLY shared state is a folder on disk. Optionally one
client is SIGKILLed mid-training; in async mode the survivors keep going and
still converge — no server, no coordinator, nothing to restart.

Also demonstrates the store transports: ``--transport delta`` ships sparse
diffs against a content-hashed base blob, and ``cache+`` folders skip
re-downloading unchanged peer blobs (per-key version metadata).

    PYTHONPATH=src python examples/multiprocess_federation.py
    PYTHONPATH=src python examples/multiprocess_federation.py --crash --nodes 4
    PYTHONPATH=src python examples/multiprocess_federation.py --transport delta
"""
import argparse
import signal
import tempfile
import time

import numpy as np

from repro.core import (
    AsyncFederatedNode,
    CachingFolder,
    make_folder,
    run_multiprocess,
)
from repro.core.strategies import FedAvg


def client(i: int, folder_uri: str, target: float, epochs: int, transport: str,
           hang_after: int | None = None):
    """Quadratic consensus client (module-level: spawn must pickle it).

    Local 'training' pulls w toward this client's own target; federation mixes
    in the peers. With FedAvg the fleet converges near the mean of targets.
    ``hang_after`` parks the client after that many federation rounds so an
    injected SIGKILL reliably lands mid-training.
    """
    folder = make_folder(folder_uri)
    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=folder,
                              node_id=f"client{i}", transport=transport)
    w = np.zeros((8,), np.float32)
    for epoch in range(epochs):
        w = w + 0.3 * (np.float32(target) - w)  # local step
        aggregated = node.update_parameters({"w": w}, num_examples=10)
        if aggregated is not None:
            w = aggregated["w"]
        if hang_after is not None and epoch + 1 >= hang_after:
            while True:  # mid-training: wait for the SIGKILL
                time.sleep(0.05)
        time.sleep(0.1)
    out = {"final": float(w.mean()), "pushes": node.num_pushes,
           "aggregations": node.num_aggregations}
    if isinstance(folder, CachingFolder):
        out["cache"] = folder.cache_stats()
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--transport", default="full",
                    help="legacy name (full/quantized/delta/delta_q/topk) or "
                         "a pipeline spec string such as 'delta(chain=4)|npz' "
                         "or 'topk(adaptive)'")
    ap.add_argument("--no-cache", action="store_true",
                    help="read the folder directly instead of through cache+")
    ap.add_argument("--crash", action="store_true",
                    help="SIGKILL the last client mid-training")
    ap.add_argument("--store", default=None,
                    help="shared folder path (default: fresh temp dir)")
    args = ap.parse_args(argv)

    shared_dir = args.store or tempfile.mkdtemp(prefix="flwr_serverless_mp_")
    folder_uri = ("" if args.no_cache else "cache+") + shared_dir
    print(f"weight store: {shared_dir}  (transport={args.transport})")

    targets = [float(i) for i in range(args.nodes)]
    clients = [
        (client, (i, folder_uri, targets[i], args.epochs, args.transport),
         {"hang_after": 3 if (args.crash and i == args.nodes - 1) else None})
        for i in range(args.nodes)
    ]
    kill_after = {args.nodes - 1: 8.0} if args.crash else None
    results = run_multiprocess(clients, names=[f"client{i}" for i in range(args.nodes)],
                               kill_after=kill_after, join_timeout=300.0)

    for r in results:
        if r.error is not None:
            crashed = r.exitcode == -signal.SIGKILL
            print(f"{r.node_id}: {'SIGKILLED mid-training' if crashed else r.error} "
                  f"(exit code {r.exitcode})")
        else:
            print(f"{r.node_id}: final={r.result['final']:.3f} "
                  f"pushes={r.result['pushes']} aggregations={r.result['aggregations']}"
                  + (f" cache={r.result['cache']}" if "cache" in r.result else ""))
    survivors = [r for r in results if r.error is None]
    finals = [r.result["final"] for r in survivors]
    spread = f"consensus spread {max(finals) - min(finals):.3f} " if finals else ""
    print(f"{len(survivors)}/{args.nodes} clients finished; "
          f"{spread}(targets spanned {max(targets) - min(targets):.1f})")


if __name__ == "__main__":
    main()
