"""Fleet chaos soak — the multi-host launcher, from laptop to shared mount.

The same ``FleetSpec`` drives all three deployments; nothing but the store
path changes, because the fleet coordinates *through the folder alone*
(spec, slot claims, heartbeats, results are all ``fleet/`` blobs — no
coordinator in the data path):

1. **Single host, one command** (this script, or ``repro.fleet launch``):
   two in-process workers partition the fleet, chaos kills + restarts
   included::

       PYTHONPATH=src python examples/fleet_soak.py
       PYTHONPATH=src python examples/fleet_soak.py --nodes 16 --kills 3 --runner process

2. **Two terminals = two "hosts"** (what CI's soak-smoke job does)::

       # terminal 1
       PYTHONPATH=src python -m repro.fleet init --store /tmp/soak \\
           --nodes 8 --rounds 8 --chaos-kills 2 --seed 7
       PYTHONPATH=src python -m repro.fleet worker --store /tmp/soak \\
           --worker-id hostA --max-slots 4
       # terminal 2
       PYTHONPATH=src python -m repro.fleet worker --store /tmp/soak \\
           --worker-id hostB --max-slots 4
       # either terminal (or a third, read-only)
       PYTHONPATH=src python -m repro.fleet report --store /tmp/soak --assert-passed

3. **Real machines**: point ``--store`` at a shared mount — NFS, gcsfuse,
   s3fs — and run ``worker`` once per machine. Slot claims use link(2)-based
   atomic creates (atomic on NFS), workers never talk to each other, and any
   host can run ``watch``/``report``. Sharded stores compose:
   ``--store "shard16+/mnt/shared/soak"`` keeps per-push scans O(group) at
   10³+ nodes while the control blobs land in the base directory.

The soak passes only if every node finished its rounds, every SIGKILLed
node's restarted incarnation reports ``resumed=True`` (counter + params +
strategy state recovered from its own deposits), and every worker
independently computed the same fleet-wide ``state_hash``.
"""
import argparse
import tempfile

from repro.core import ChaosSpec, FleetSpec, run_fleet_local


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None,
                    help="shared folder URI (default: fresh temp dir); "
                         "cache+/shard<G>+ wrappers compose")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--kills", type=int, default=2,
                    help="seeded SIGKILL-then-restart victims")
    ap.add_argument("--stalls", type=int, default=1,
                    help="seeded slow-node stall victims")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--runner", choices=("thread", "process"), default="thread",
                    help="'process' = one OS process per node (real SIGKILLs); "
                         "'thread' = in-process soak (fast, 10^2-node scale)")
    ap.add_argument("--transport", default=None,
                    help="pipeline spec, e.g. 'delta(chain=4)|npz'")
    args = ap.parse_args(argv)

    store = args.store or tempfile.mkdtemp(prefix="fleet_soak_")
    spec = FleetSpec(
        store_uri=store,
        num_nodes=args.nodes,
        rounds=args.rounds,
        runner=args.runner,
        transport=args.transport,
        round_sleep=0.02 if args.runner == "thread" else 0.05,
        chaos=ChaosSpec(seed=args.seed, kills=args.kills, stalls=args.stalls,
                        restart_after=0.3, stall_duration=0.3),
    )
    print(f"soaking {spec.num_nodes} nodes x {spec.rounds} rounds over {store!r} "
          f"({args.workers} workers, runner={spec.runner}, "
          f"kills={args.kills}, stalls={args.stalls}, seed={args.seed})")
    report = run_fleet_local(spec, num_workers=args.workers)
    print()
    print(report.summary())
    if report.recovery_latency:
        for node, latency in sorted(report.recovery_latency.items()):
            print(f"  {node}: SIGKILL -> resumed push in {latency:.2f}s")
    raise SystemExit(0 if report.passed else 1)


if __name__ == "__main__":
    main()
