"""Hierarchical gossip — a 2-level summary tree over a ``shard<G>x<L>`` store.

A single-tier ring (``shard<G>+``) keeps per-step scans O(group), but every
folder still collects one summary per foreign group: O(G) keys, and a pull's
bounded rotating sample needs O(G) pulls to cover the fleet. ``shard<G>x2+``
folds level-0 group summaries into super-summaries along deterministically
elected aggregator groups (stable hash of ``(level, origin)`` — no
coordinator, every node derives the same election), forwarded on a ring that
is ``⌈√G⌉``× shorter and down-copied back into every member folder. Per-push
work and the staleness window then scale with the branching factor, not G.

    PYTHONPATH=src python examples/hierarchical_gossip.py
    PYTHONPATH=src python examples/hierarchical_gossip.py --nodes 36 --groups 9 --levels 2

The demo federates threaded clients over a 2-level in-process store, prints
the derived tree (segments, elected aggregators, per-level rings), the
per-level gossip telemetry spans, and the exact-coverage accounting of one
pull (home peers + level-0 summaries + supers = fleet, nothing twice).
"""
import argparse
import time

import numpy as np

from repro.core import (
    AsyncFederatedNode,
    GossipHierarchy,
    InMemoryFolder,
    ShardedFolders,
    ShardedWeightStore,
    Telemetry,
    balanced_groups,
    run_threaded,
)
from repro.core.gossip import GROUP_PEER_PREFIX
from repro.core.strategies import FedAvg


def print_tree(hier: GossipHierarchy) -> None:
    print(f"summary tree: {hier.num_groups} groups, {hier.levels} levels, "
          f"branching {hier.branching}, diameter {hier.diameter()} rounds")
    for level in range(1, hier.levels):
        holders = {o: hier.holder(level, o) for o in range(hier.counts[level])}
        print(f"  level {level}: {hier.counts[level]} origins, elected "
              f"aggregator groups {holders}")
    scope = hier.scope(0)
    pretty = {lvl: sorted(origins) for lvl, origins in scope.items()}
    print(f"  group 0 pull scope (level -> foreign origins): {pretty}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=18)
    ap.add_argument("--groups", type=int, default=9)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=8)
    args = ap.parse_args(argv)

    folders = ShardedFolders(args.groups, levels=args.levels,
                             factory=lambda g: InMemoryFolder())
    node_ids = [f"client{i}" for i in range(args.nodes)]
    mapping = balanced_groups(node_ids, args.groups)
    targets = {nid: float(i) for i, nid in enumerate(node_ids)}
    print(f"weight store: shard{args.groups}x{args.levels}+memory://")
    print_tree(GossipHierarchy(args.groups, args.levels))

    # one store shared by the threaded clients (exactly what a fleet of
    # processes would reconstruct per-node from the URI), with telemetry on
    # so the per-level gossip phases show up as named spans
    tel = Telemetry("hierarchical_gossip", enabled=True)
    store = ShardedWeightStore(folders, group_of=mapping)
    store.attach_telemetry(tel)
    finals, nodes = {}, {}

    def client(nid):
        node = AsyncFederatedNode(strategy=FedAvg(), store=store, node_id=nid)
        nodes[nid] = node
        w = np.zeros((8,), np.float32)
        for _ in range(args.epochs):
            w = w + 0.3 * (np.float32(targets[nid]) - w)  # local step
            aggregated = node.update_parameters({"w": w}, num_examples=10)
            if aggregated is not None:
                w = aggregated["w"]
            time.sleep(0.01)
        finals[nid] = (float(w.mean()), w)

    results = run_threaded([lambda n=n: client(n) for n in node_ids])
    errors = [r for r in results if r.error is not None]
    assert not errors, [r.traceback for r in errors]

    # settle: ring-order re-pushes (one member per group, same weights, same
    # example counts) carry the last epoch's summaries up the tree, around
    # the shorter rings, and back down — ``diameter()`` rounds bound it
    hier = store.hierarchy
    rep = {}
    for nid in node_ids:
        rep.setdefault(mapping[nid], nid)
    for _ in range(hier.diameter()):
        for g in sorted(rep):
            nid = rep[g]
            nodes[nid].update_parameters({"w": finals[nid][1]}, num_examples=10)

    values = [v for v, _ in finals.values()]
    print(f"\n{args.nodes} clients, {args.epochs} epochs: consensus spread "
          f"{max(values) - min(values):.2f} (targets spanned "
          f"{max(targets.values()) - min(targets.values()):.1f})")
    print(f"summary refreshes={store.num_summary_refreshes} "
          f"forwards={store.num_summary_forwards} "
          f"super_folds={store.num_super_folds}")

    spans = {name: st for name, st in tel.recorder.phase_stats().items()
             if name.startswith("gossip")}
    print("\nper-level gossip spans (count, total ms):")
    for name in sorted(spans):
        st = spans[name]
        print(f"  {name:20s} n={st['count']:5d} "
              f"total={st['total_s'] * 1e3:8.1f}ms")

    # exact coverage: one pull weighs the foreign fleet exactly once —
    # home peers as real updates, segment siblings as level-0 summaries,
    # the rest as supers
    probe = node_ids[0]
    pulled = store.pull(exclude=probe)
    home = [u for u in pulled if not u.node_id.startswith(GROUP_PEER_PREFIX)]
    l0 = [u for u in pulled if u.node_id.startswith(GROUP_PEER_PREFIX)
          and not u.node_id.startswith(f"{GROUP_PEER_PREFIX}L")]
    supers = [u for u in pulled if u.node_id.startswith(f"{GROUP_PEER_PREFIX}L")]
    total = sum(u.num_examples for u in pulled)
    expect = 10 * (args.nodes - 1)  # every client deposited 10 examples
    print(f"\npull coverage for {probe}: {len(home)} home peers + "
          f"{len(l0)} level-0 summaries + {len(supers)} supers "
          f"= {total} examples (10 x (fleet - self) = {expect})")
    assert total == expect, "coverage must be exact — no double counting"


if __name__ == "__main__":
    main()
