"""End-to-end driver: federated training of a ~100M-param language model.

Serverless async nodes train a 12-layer / d512 decoder LM (≈95M params,
Pythia-style, plus LoRA adapters on the attention q-projections) on disjoint
shards of a synthetic WikiText stream, federating through a *real*
``WeightStore`` (delta-chain transport by default) after every epoch — the
paper's §4.4 experiment scaled to the "fleet of affordable compute nodes"
setting its §5 aspires to.

    PYTHONPATH=src python examples/federated_llm.py                 # ~100M, 300 steps
    PYTHONPATH=src python examples/federated_llm.py --smoke         # 2 min version
    PYTHONPATH=src python examples/federated_llm.py --adapters-only # LoRA federation

``--adapters-only`` demonstrates leaf-family subset federation: nodes ship
and aggregate ONLY the ``adapters`` leaf family (``family(adapters=full)``
transport + ``PartialFedAvg(families=...)``), so each round moves ~2 orders
of magnitude fewer bytes while every other weight stays node-local.
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.core import AsyncFederatedNode, FederatedCallback, InMemoryFolder, run_threaded
from repro.core.partition import partition_sequence_dataset
from repro.core.strategies import FedAvg
from repro.data import lm_batch_iterator, make_synthetic_wikitext
from repro.models import ModelConfig, build_model
from repro.optim import adamw, chain_clip
from repro.training import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true")
ap.add_argument("--nodes", type=int, default=3)
ap.add_argument("--epochs", type=int, default=None)
ap.add_argument("--transport", default="delta(chain=4)",
                help="weight-store pipeline spec, e.g. full, delta(chain=4), "
                     "'family(adapters=full,norms=delta)'")
ap.add_argument("--adapters-only", action="store_true",
                help="LoRA-style federation: ship + aggregate only the "
                     "adapters leaf family; all other weights stay local")
args = ap.parse_args()

CFG = ModelConfig(
    name="fedlm-95m",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=50304, activation="gelu", dtype="float32", lora_rank=8,
    source="Pythia-style ~100M (arXiv:2304.01373) + LoRA (arXiv:2106.09685)",
)
if args.smoke:
    CFG = CFG.replace(n_layers=4, d_model=256, d_ff=1024, vocab_size=2048)

SEQ, BATCH = 128, 8
EPOCHS = args.epochs or (2 if args.smoke else 10)
STEPS = 10 if args.smoke else 30   # per epoch per node → 3 nodes × 300 steps total

model = build_model(CFG)
n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(jax.eval_shape(model.init, jax.random.PRNGKey(0))))
print(f"model: {CFG.name}  params={n_params/1e6:.1f}M  nodes={args.nodes}  "
      f"steps/node={EPOCHS * STEPS}  "
      f"wire={'family(adapters=full)' if args.adapters_only else args.transport}")

data = make_synthetic_wikitext(vocab_size=CFG.vocab_size, train_tokens=400_000, seed=0)
shards = partition_sequence_dataset(data.train_tokens, args.nodes)
folder = InMemoryFolder()
init_params = model.init(jax.random.PRNGKey(0))  # common init


def evaluate(params):
    accs, losses = [], []
    for i, batch in enumerate(lm_batch_iterator(data.test_tokens, batch_size=8, seq_len=SEQ, seed=9)):
        if i >= 4:
            break
        loss, m = model.loss(params, batch)
        losses.append(float(loss)); accs.append(float(m["accuracy"]))
    return float(np.mean(losses)), float(np.mean(accs))


def client(i: int):
    trainer = Trainer(
        loss_fn=lambda p, b, r: model.loss(p, b),
        optimizer=chain_clip(adamw(3e-4), 1.0),
        init_params=init_params,
        seed=i, name=f"node{i}",
    )
    if args.adapters_only:
        # families= wires both halves of subset federation: the node's store
        # ships family(adapters=full) blobs, and the default strategy becomes
        # PartialFedAvg(families=...) so non-adapter leaves stay personal.
        node = AsyncFederatedNode(
            shared_folder=folder, node_id=f"node{i}", families=("adapters",))
    else:
        node = AsyncFederatedNode(
            strategy=FedAvg(), shared_folder=folder, node_id=f"node{i}",
            transport=args.transport)
    cb = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
    trainer.fit(lambda e: lm_batch_iterator(shards[i], batch_size=BATCH, seq_len=SEQ, seed=i, epoch=e),
                epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[cb], verbose=(i == 0))
    loss, acc = evaluate(trainer.params)
    stats = node.transport_stats()
    return {"node": f"node{i}", "eval_loss": round(loss, 4), "next_token_acc": round(acc, 4),
            "aggregations": node.num_aggregations,
            "mb_written": round(stats["bytes_written"] / 1e6, 2),
            "mb_read": round(stats["bytes_read"] / 1e6, 2)}


t0 = time.time()
results = run_threaded([lambda i=i: client(i) for i in range(args.nodes)])
for r in results:
    assert r.error is None, r.traceback
    print(json.dumps(r.result))
print(f"wall time: {time.time() - t0:.1f}s (no federation server was ever started)")
