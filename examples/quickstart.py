"""Quickstart — the paper's usage pattern, end to end, in ~40 lines.

Two clients train the paper's MNIST CNN on disjoint label partitions and
federate asynchronously through a shared folder (here: a temp dir on disk —
point it at an NFS/S3 mount in production). No server anywhere.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax

from repro.api import connect
from repro.core import AsyncFederatedNode, FederatedCallback, run_threaded
from repro.core.partition import partition_dataset
from repro.core.strategies import FedAvg
from repro.data import batch_iterator, make_synthetic_mnist
from repro.models.cnn import MnistCNN
from repro.optim import adam
from repro.training import Trainer

EPOCHS, STEPS, BATCH = 5, 20, 32

data = make_synthetic_mnist(num_train=2000, num_test=500)
shards = partition_dataset(data.x_train, data.y_train, num_nodes=2, skew=0.9)
shared_dir = tempfile.mkdtemp(prefix="flwr_serverless_")
print(f"weight store: {shared_dir}")


def client(i: int):
    model = MnistCNN()
    trainer = Trainer(
        loss_fn=lambda p, b, r: model.loss(p, b),
        optimizer=adam(1e-3),
        init_params=model.init(jax.random.PRNGKey(0)),  # common init
        seed=i,
        name=f"client{i}",
    )
    # --- the paper's three-line federation setup -------------------------
    node = AsyncFederatedNode(strategy=FedAvg(), store=connect(shared_dir),
                              node_id=f"client{i}")
    callback = FederatedCallback(node, num_examples_per_epoch=STEPS * BATCH)
    # ----------------------------------------------------------------------
    x, y = shards[i]
    trainer.fit(lambda e: batch_iterator(x, y, batch_size=BATCH, seed=i, epoch=e),
                epochs=EPOCHS, steps_per_epoch=STEPS, callbacks=[callback], verbose=True)
    import numpy as np

    logits = model.apply(trainer.params, data.x_test)
    acc = float((np.argmax(np.asarray(logits), -1) == data.y_test).mean())
    print(f"client{i}: test accuracy {acc:.3f} "
          f"(pushes={node.num_pushes}, aggregations={node.num_aggregations})")
    return acc


results = run_threaded([lambda: client(0), lambda: client(1)])
for r in results:
    assert r.error is None, r.traceback
print("done — no server was harmed (or started) in this experiment.")
